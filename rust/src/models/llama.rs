//! LLaMA-7b + LoRA inventory (Touvron et al. 2023; Hu et al. 2021) — the
//! paper's Table 4/7 and Figure 4 workload.
//!
//! The base model (6.7B params) is frozen and counted as resident bytes;
//! the trainable inventory is the LoRA adapter set: rank-8 A/B pairs on
//! every linear projection (q/k/v/o/gate/up/down), which lands at ~20M
//! trainable params — matching the paper's 153 MiB Adam state (2N·4B).

use super::Inventory;

pub struct LlamaCfg {
    pub layers: usize,
    pub hidden: usize,
    pub intermediate: usize,
    pub vocab: usize,
}

pub const LLAMA_7B: LlamaCfg =
    LlamaCfg { layers: 32, hidden: 4096, intermediate: 11008, vocab: 32000 };

/// Full (frozen) base parameter count.
pub fn llama_base_params(cfg: &LlamaCfg) -> u64 {
    let h = cfg.hidden as u64;
    let i = cfg.intermediate as u64;
    let per_layer = 4 * h * h + 3 * h * i + 2 * h; // attn + mlp + 2 rmsnorm
    cfg.vocab as u64 * h * 2 + cfg.layers as u64 * per_layer + h
}

/// LoRA adapters over every linear projection of every layer.
pub fn llama7b_lora(rank: usize) -> Inventory {
    let cfg = &LLAMA_7B;
    let mut inv = Inventory::new(&format!("llama7b_lora_r{rank}"));
    let h = cfg.hidden;
    let i = cfg.intermediate;
    for l in 0..cfg.layers {
        let p = format!("model.layers.{l}");
        for proj in ["q_proj", "k_proj", "v_proj", "o_proj"] {
            inv.push(format!("{p}.self_attn.{proj}.lora_A"), &[rank, h]);
            inv.push(format!("{p}.self_attn.{proj}.lora_B"), &[h, rank]);
        }
        for (proj, inf, outf) in
            [("gate_proj", h, i), ("up_proj", h, i), ("down_proj", i, h)]
        {
            inv.push(format!("{p}.mlp.{proj}.lora_A"), &[rank, inf]);
            inv.push(format!("{p}.mlp.{proj}.lora_B"), &[outf, rank]);
        }
    }
    inv.frozen_bytes = llama_base_params(cfg) * 4; // fp32 resident base
    inv
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn base_is_6_7b() {
        let n = llama_base_params(&LLAMA_7B);
        assert!((6_500_000_000..6_900_000_000).contains(&n), "{n}");
    }

    #[test]
    fn lora_r8_is_20m_trainable() {
        // Paper Table 4: Adam = 153 MiB = 2N·4B -> N ≈ 20.0M.
        let n = llama7b_lora(8).param_count();
        assert!((19_500_000..20_500_000).contains(&n), "{n}");
    }

    #[test]
    fn frozen_base_dominates_e2e() {
        // Paper: end-to-end 24.9 GiB ≈ frozen fp32 base (25 GiB).
        let inv = llama7b_lora(8);
        let gib = inv.frozen_bytes as f64 / (1u64 << 30) as f64;
        assert!((24.0..26.5).contains(&gib), "{gib}");
    }
}
