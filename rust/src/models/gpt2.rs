//! GPT-2 inventories: 124M (HF `gpt2`, fine-tuning Tables 4/8) and the
//! Megatron 345M variant (pre-training, Table 3).

use super::Inventory;

pub struct Gpt2Cfg {
    pub layers: usize,
    pub hidden: usize,
    pub vocab: usize,
    pub max_pos: usize,
}

pub fn gpt2(name: &str, cfg: &Gpt2Cfg) -> Inventory {
    let mut inv = Inventory::new(name);
    let h = cfg.hidden;
    inv.embedding("wte", cfg.vocab, h);
    inv.embedding("wpe", cfg.max_pos, h);
    for l in 0..cfg.layers {
        let p = format!("h.{l}");
        inv.norm(&format!("{p}.ln_1"), h);
        // HF stores fused qkv as c_attn (h, 3h) + bias.
        inv.push(format!("{p}.attn.c_attn.weight"), &[h, 3 * h]);
        inv.push(format!("{p}.attn.c_attn.bias"), &[3 * h]);
        inv.push(format!("{p}.attn.c_proj.weight"), &[h, h]);
        inv.push(format!("{p}.attn.c_proj.bias"), &[h]);
        inv.norm(&format!("{p}.ln_2"), h);
        inv.push(format!("{p}.mlp.c_fc.weight"), &[h, 4 * h]);
        inv.push(format!("{p}.mlp.c_fc.bias"), &[4 * h]);
        inv.push(format!("{p}.mlp.c_proj.weight"), &[4 * h, h]);
        inv.push(format!("{p}.mlp.c_proj.bias"), &[h]);
    }
    inv.norm("ln_f", h);
    // lm_head tied to wte (no extra parameters).
    inv
}

pub fn gpt2_124m() -> Inventory {
    gpt2("gpt2_124m", &Gpt2Cfg { layers: 12, hidden: 768, vocab: 50257, max_pos: 1024 })
}

pub fn gpt2_345m() -> Inventory {
    gpt2("gpt2_345m", &Gpt2Cfg { layers: 24, hidden: 1024, vocab: 50257, max_pos: 1024 })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_is_124m() {
        let n = gpt2_124m().param_count();
        assert!((123_000_000..126_000_000).contains(&n), "{n}");
    }

    #[test]
    fn megatron_is_354m() {
        // Paper Table 3: Adam = 2.6 GiB -> N ≈ 349M.
        let n = gpt2_345m().param_count();
        assert!((340_000_000..360_000_000).contains(&n), "{n}");
    }
}
