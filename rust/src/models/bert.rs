//! BERT-family inventories: Megatron BERT-345M (the paper's pre-training
//! workload, Table 3 — trained with NVIDIA Megatron-LM code), BERT-base
//! (fine-tuning, Table 6), RoBERTa-base and ALBERT-base-v2 (SQuAD,
//! Table 8).

use super::Inventory;

pub struct EncoderCfg {
    pub layers: usize,
    pub hidden: usize,
    pub ff: usize,
    pub vocab: usize,
    pub max_pos: usize,
    pub type_vocab: usize,
}

/// Standard BERT encoder stack (HF layout, biases everywhere).
pub fn bert_encoder(name: &str, cfg: &EncoderCfg, with_pooler: bool) -> Inventory {
    let mut inv = Inventory::new(name);
    let h = cfg.hidden;
    inv.embedding("embeddings.word", cfg.vocab, h);
    inv.embedding("embeddings.position", cfg.max_pos, h);
    if cfg.type_vocab > 0 {
        inv.embedding("embeddings.token_type", cfg.type_vocab, h);
    }
    inv.norm("embeddings.LayerNorm", h);
    for l in 0..cfg.layers {
        let p = format!("encoder.layer.{l}");
        for proj in ["query", "key", "value"] {
            inv.linear(&format!("{p}.attention.self.{proj}"), h, h);
        }
        inv.linear(&format!("{p}.attention.output.dense"), h, h);
        inv.norm(&format!("{p}.attention.output.LayerNorm"), h);
        inv.linear(&format!("{p}.intermediate.dense"), h, cfg.ff);
        inv.linear(&format!("{p}.output.dense"), cfg.ff, h);
        inv.norm(&format!("{p}.output.LayerNorm"), h);
    }
    if with_pooler {
        inv.linear("pooler.dense", h, h);
    }
    inv
}

pub fn bert_base() -> Inventory {
    bert_encoder(
        "bert_base",
        &EncoderCfg { layers: 12, hidden: 768, ff: 3072, vocab: 30522, max_pos: 512, type_vocab: 2 },
        true,
    )
}

/// Megatron BERT-345M (L=24, H=1024) — the paper's pre-training target.
pub fn bert_345m() -> Inventory {
    bert_encoder(
        "bert_345m",
        &EncoderCfg { layers: 24, hidden: 1024, ff: 4096, vocab: 30522, max_pos: 512, type_vocab: 2 },
        true,
    )
}

pub fn roberta_base() -> Inventory {
    bert_encoder(
        "roberta_base",
        &EncoderCfg { layers: 12, hidden: 768, ff: 3072, vocab: 50265, max_pos: 514, type_vocab: 1 },
        true,
    )
}

/// ALBERT-base-v2: factorized embedding (E=128) + ONE shared encoder layer.
pub fn albert_base_v2() -> Inventory {
    let mut inv = Inventory::new("albert_base_v2");
    let (e, h, ff) = (128, 768, 3072);
    inv.embedding("embeddings.word", 30000, e);
    inv.embedding("embeddings.position", 512, e);
    inv.embedding("embeddings.token_type", 2, e);
    inv.norm("embeddings.LayerNorm", e);
    inv.linear("embedding_hidden_mapping_in", e, h);
    // single shared layer (reused 12x at runtime; parameters stored once)
    let p = "encoder.albert_layer";
    for proj in ["query", "key", "value"] {
        inv.linear(&format!("{p}.attention.{proj}"), h, h);
    }
    inv.linear(&format!("{p}.attention.dense"), h, h);
    inv.norm(&format!("{p}.attention.LayerNorm"), h);
    inv.linear(&format!("{p}.ffn"), h, ff);
    inv.linear(&format!("{p}.ffn_output"), ff, h);
    inv.norm(&format!("{p}.full_layer_layer_norm"), h);
    inv.linear("pooler", h, h);
    inv
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bert_base_is_110m() {
        let n = bert_base().param_count();
        assert!((108_000_000..112_000_000).contains(&n), "{n}");
    }

    #[test]
    fn megatron_bert_is_345m_class() {
        // Paper Table 3: Adam = 2.5 GiB = 2N floats -> N ≈ 335M.
        let n = bert_345m().param_count();
        assert!((330_000_000..360_000_000).contains(&n), "{n}");
    }

    #[test]
    fn roberta_base_is_125m() {
        let n = roberta_base().param_count();
        assert!((123_000_000..128_000_000).contains(&n), "{n}");
    }

    #[test]
    fn albert_is_tiny_via_sharing() {
        // ALBERT-base-v2: 11.7M parameters (HF).
        let n = albert_base_v2().param_count();
        assert!((11_000_000..12_500_000).contains(&n), "{n}");
    }
}
