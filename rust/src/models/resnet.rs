//! ResNet-50 (He et al. 2016) parameter inventory, torchvision layout.

use super::Inventory;

/// Bottleneck widths per stage and block counts for ResNet-50.
const STAGES: [(usize, usize); 4] = [(64, 3), (128, 4), (256, 6), (512, 3)];
const EXPANSION: usize = 4;

/// Build the ResNet-50 inventory. `classes` = 1000 (ImageNet) or 100
/// (CIFAR100 — the paper trains the same trunk with a smaller head).
pub fn resnet50(classes: usize) -> Inventory {
    let mut inv = Inventory::new(&format!("resnet50_c{classes}"));
    inv.conv("conv1", 64, 3, 7);
    inv.norm("bn1", 64);
    let mut cin = 64;
    for (stage_idx, (width, blocks)) in STAGES.iter().enumerate() {
        let (width, blocks) = (*width, *blocks);
        let cout = width * EXPANSION;
        for b in 0..blocks {
            let p = format!("layer{}.{}", stage_idx + 1, b);
            inv.conv(&format!("{p}.conv1"), width, cin, 1);
            inv.norm(&format!("{p}.bn1"), width);
            inv.conv(&format!("{p}.conv2"), width, width, 3);
            inv.norm(&format!("{p}.bn2"), width);
            inv.conv(&format!("{p}.conv3"), cout, width, 1);
            inv.norm(&format!("{p}.bn3"), cout);
            if b == 0 {
                // projection shortcut on the first block of every stage
                inv.conv(&format!("{p}.downsample.0"), cout, cin, 1);
                inv.norm(&format!("{p}.downsample.1"), cout);
            }
            cin = cout;
        }
    }
    inv.linear("fc", 512 * EXPANSION, classes);
    inv
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn imagenet_param_count() {
        // torchvision resnet50: 25,557,032 parameters.
        assert_eq!(resnet50(1000).param_count(), 25_557_032);
    }

    #[test]
    fn cifar_head_shrinks() {
        let full = resnet50(1000).param_count();
        let cifar = resnet50(100).param_count();
        assert_eq!(full - cifar, (2048 * 900 + 900) as u64);
    }

    #[test]
    fn mostly_conv_tensors() {
        let inv = resnet50(1000);
        let convs = inv.tensors.iter().filter(|t| t.shape.len() == 4).count();
        assert_eq!(convs, 53); // 53 conv layers in resnet50
    }
}
