//! Synthetic data substrates.
//!
//! The paper's datasets (ImageNet, COCO, WMT32k, BookCorpus&Wikipedia,
//! GLUE) are license/size-gated; the optimizer claims only need workloads
//! with comparable gradient structure, so we build:
//!
//! * [`corpus`] — a real embedded tiny text corpus + byte tokenizer and a
//!   Zipf-distributed synthetic token stream (language-modeling stand-in).
//! * [`images`] — class-conditional Gaussian/striped image generator
//!   (classification stand-in; each class has a distinct mean pattern so
//!   small CNNs/MLPs can actually learn).
//! * [`Batcher`] — deterministic seeded batch iterator.

pub mod corpus;
pub mod images;

pub use corpus::{ByteTokenizer, CharLmDataset, ZipfCorpus, TINY_CORPUS};
pub use images::SyntheticImages;

use crate::util::rng::Pcg32;

/// Deterministic index batcher with reshuffling between epochs.
pub struct Batcher {
    n: usize,
    batch: usize,
    order: Vec<u32>,
    cursor: usize,
    rng: Pcg32,
}

impl Batcher {
    pub fn new(n: usize, batch: usize, seed: u64) -> Batcher {
        assert!(n > 0 && batch > 0);
        let mut b = Batcher {
            n,
            batch,
            order: (0..n as u32).collect(),
            cursor: 0,
            rng: Pcg32::new(seed),
        };
        b.shuffle();
        b
    }

    fn shuffle(&mut self) {
        for i in (1..self.order.len()).rev() {
            let j = self.rng.below(i + 1);
            self.order.swap(i, j);
        }
    }

    /// Next batch of indices (wraps epochs, reshuffling each time).
    pub fn next_batch(&mut self, out: &mut Vec<u32>) {
        out.clear();
        for _ in 0..self.batch {
            if self.cursor >= self.n {
                self.cursor = 0;
                self.shuffle();
            }
            out.push(self.order[self.cursor]);
            self.cursor += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batches_cover_epoch() {
        let mut b = Batcher::new(10, 3, 0);
        let mut seen = std::collections::BTreeSet::new();
        let mut buf = Vec::new();
        for _ in 0..4 {
            b.next_batch(&mut buf);
            seen.extend(buf.iter().copied());
        }
        // 12 draws from 10 items: all items seen at least once.
        assert_eq!(seen.len(), 10);
    }

    #[test]
    fn deterministic_across_seeds() {
        let mut a = Batcher::new(100, 7, 9);
        let mut b = Batcher::new(100, 7, 9);
        let (mut x, mut y) = (Vec::new(), Vec::new());
        for _ in 0..5 {
            a.next_batch(&mut x);
            b.next_batch(&mut y);
            assert_eq!(x, y);
        }
    }
}
