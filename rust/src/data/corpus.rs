//! Text substrates for the language-modeling experiments.
//!
//! * [`TINY_CORPUS`] — a real English text embedded in the binary: the
//!   end-to-end driver trains a char-LM on it and the loss curve is
//!   meaningful (it is real natural language, not noise).
//! * [`ByteTokenizer`] — printable-ASCII tokenizer matching the AOT
//!   models' `vocab = 96`.
//! * [`ZipfCorpus`] — synthetic Zipf(1.1) token stream for scale tests.

use crate::util::rng::{zipf_harmonic, Pcg32};

/// Original expository English prose (an essay on the history of
/// calculation), ~18 KB. Enough for a few hundred distinct 128-token
/// windows.
pub const TINY_CORPUS: &str = include_str!("tiny_corpus.txt");

/// Maps bytes to [0, 96): printable ASCII 32..=126 -> 1..=95, everything
/// else (incl. newline) -> 0.
#[derive(Clone, Copy, Debug, Default)]
pub struct ByteTokenizer;

impl ByteTokenizer {
    pub const VOCAB: usize = 96;

    pub fn encode(&self, text: &str, out: &mut Vec<i32>) {
        out.clear();
        out.extend(text.bytes().map(|b| {
            if (32..=126).contains(&b) {
                (b - 31) as i32
            } else {
                0
            }
        }));
    }

    pub fn decode(&self, tokens: &[i32]) -> String {
        tokens
            .iter()
            .map(|&t| {
                if (1..=95).contains(&t) {
                    (t as u8 + 31) as char
                } else {
                    '\n'
                }
            })
            .collect()
    }
}

/// Char-LM dataset: random (tokens, targets) windows over an encoded text.
pub struct CharLmDataset {
    tokens: Vec<i32>,
    pub seq_len: usize,
    rng: Pcg32,
}

impl CharLmDataset {
    pub fn new(text: &str, seq_len: usize, seed: u64) -> CharLmDataset {
        let mut tokens = Vec::new();
        ByteTokenizer.encode(text, &mut tokens);
        assert!(
            tokens.len() > seq_len + 1,
            "corpus too short: {} <= {}",
            tokens.len(),
            seq_len + 1
        );
        CharLmDataset { tokens, seq_len, rng: Pcg32::new(seed) }
    }

    pub fn len_tokens(&self) -> usize {
        self.tokens.len()
    }

    /// Sampling-RNG snapshot for checkpointing: restoring it resumes the
    /// exact window stream, making resumed training runs bit-identical.
    pub fn rng_state(&self) -> (u64, u64) {
        self.rng.state()
    }

    pub fn set_rng_state(&mut self, state: u64, inc: u64) {
        self.rng = Pcg32::from_state(state, inc);
    }

    /// Fill `(batch, seq)` inputs and next-char targets.
    pub fn sample_batch(&mut self, batch: usize, inputs: &mut Vec<i32>, targets: &mut Vec<i32>) {
        inputs.clear();
        targets.clear();
        for _ in 0..batch {
            let start = self.rng.below(self.tokens.len() - self.seq_len - 1);
            inputs.extend_from_slice(&self.tokens[start..start + self.seq_len]);
            targets.extend_from_slice(&self.tokens[start + 1..start + self.seq_len + 1]);
        }
    }
}

/// Synthetic Zipf token stream (stands in for web-scale corpora: matches
/// the rank-frequency skew real text has, so embedding-gradient sparsity
/// patterns are realistic).
pub struct ZipfCorpus {
    vocab: usize,
    harmonic: f64,
    s: f64,
    rng: Pcg32,
}

impl ZipfCorpus {
    pub fn new(vocab: usize, s: f64, seed: u64) -> ZipfCorpus {
        ZipfCorpus { vocab, harmonic: zipf_harmonic(vocab, s), s, rng: Pcg32::new(seed) }
    }

    pub fn sample_batch(&mut self, batch: usize, seq: usize, inputs: &mut Vec<i32>, targets: &mut Vec<i32>) {
        inputs.clear();
        targets.clear();
        for _ in 0..batch {
            let mut prev = self.rng.zipf(self.vocab, self.s, self.harmonic) as i32;
            for k in 0..=seq {
                // weak bigram structure: with p=0.25 repeat-ish token
                let tok = if self.rng.uniform() < 0.25 {
                    ((prev as usize + 1) % self.vocab) as i32
                } else {
                    self.rng.zipf(self.vocab, self.s, self.harmonic) as i32
                };
                if k < seq {
                    inputs.push(tok);
                }
                if k > 0 {
                    targets.push(tok);
                }
                prev = tok;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_is_real_text() {
        assert!(TINY_CORPUS.len() > 15_000, "{}", TINY_CORPUS.len());
        assert!(TINY_CORPUS.contains("the"));
    }

    #[test]
    fn tokenizer_roundtrip_printables() {
        let t = ByteTokenizer;
        let mut toks = Vec::new();
        t.encode("Hello, World! 123", &mut toks);
        assert!(toks.iter().all(|&x| (0..96).contains(&x)));
        assert_eq!(t.decode(&toks), "Hello, World! 123");
    }

    #[test]
    fn windows_are_shifted_pairs() {
        let mut ds = CharLmDataset::new(TINY_CORPUS, 16, 0);
        let (mut x, mut y) = (Vec::new(), Vec::new());
        ds.sample_batch(4, &mut x, &mut y);
        assert_eq!(x.len(), 64);
        assert_eq!(y.len(), 64);
        // each window: y[k] == x[k+1]
        for b in 0..4 {
            for k in 0..15 {
                assert_eq!(y[b * 16 + k], x[b * 16 + k + 1]);
            }
        }
    }

    #[test]
    fn zipf_batch_shapes() {
        let mut z = ZipfCorpus::new(500, 1.1, 1);
        let (mut x, mut y) = (Vec::new(), Vec::new());
        z.sample_batch(2, 8, &mut x, &mut y);
        assert_eq!(x.len(), 16);
        assert_eq!(y.len(), 16);
        assert!(x.iter().all(|&t| (0..500).contains(&t)));
    }
}
