//! Class-conditional synthetic image generator (CIFAR/ImageNet stand-in).
//!
//! Each class gets a deterministic spatial pattern (oriented sinusoidal
//! grating with class-specific frequency/phase/colour) plus Gaussian pixel
//! noise, so gradients have realistic conv structure and small models can
//! reach high accuracy — giving the Figure-1-style optimizer comparison a
//! learnable signal.

use crate::util::rng::Pcg32;

pub struct SyntheticImages {
    pub classes: usize,
    pub size: usize, // H = W
    noise: f32,
    rng: Pcg32,
}

impl SyntheticImages {
    pub fn new(classes: usize, size: usize, noise: f32, seed: u64) -> SyntheticImages {
        SyntheticImages { classes, size, noise, rng: Pcg32::new(seed) }
    }

    /// Fill a (batch, 3, H, W) f32 buffer + labels.
    pub fn sample_batch(&mut self, batch: usize, pixels: &mut Vec<f32>, labels: &mut Vec<i32>) {
        let (c, s) = (3usize, self.size);
        pixels.clear();
        pixels.reserve(batch * c * s * s);
        labels.clear();
        for _ in 0..batch {
            let y = self.rng.below(self.classes);
            labels.push(y as i32);
            let freq = 0.3 + 0.45 * (y % 7) as f32;
            let angle = (y % 5) as f32 * std::f32::consts::PI / 5.0;
            let phase = (y / 5) as f32 * 0.7;
            let (ca, sa) = (angle.cos(), angle.sin());
            for ch in 0..c {
                let ch_gain = 0.5 + 0.5 * (((y + ch * 3) % 4) as f32 / 3.0);
                for i in 0..s {
                    for j in 0..s {
                        let u = ca * i as f32 + sa * j as f32;
                        let v = (freq * u + phase).sin() * ch_gain;
                        pixels.push(v + self.noise * self.rng.normal());
                    }
                }
            }
        }
    }

    /// Sampling-RNG snapshot for checkpointing (mirrors
    /// `CharLmDataset::rng_state`).
    pub fn rng_state(&self) -> (u64, u64) {
        self.rng.state()
    }

    pub fn set_rng_state(&mut self, state: u64, inc: u64) {
        self.rng = Pcg32::from_state(state, inc);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_labels() {
        let mut g = SyntheticImages::new(10, 8, 0.1, 0);
        let (mut px, mut ys) = (Vec::new(), Vec::new());
        g.sample_batch(4, &mut px, &mut ys);
        assert_eq!(px.len(), 4 * 3 * 8 * 8);
        assert_eq!(ys.len(), 4);
        assert!(ys.iter().all(|&y| (0..10).contains(&y)));
        assert!(px.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn classes_are_distinguishable() {
        // Mean pixel pattern of class 0 differs from class 1 far beyond
        // noise level: nearest-mean classification would beat chance.
        let mut g = SyntheticImages::new(2, 8, 0.0, 0);
        let (mut px, mut ys) = (Vec::new(), Vec::new());
        let mut means = vec![vec![0.0f64; 3 * 64]; 2];
        let mut counts = [0usize; 2];
        for _ in 0..20 {
            g.sample_batch(8, &mut px, &mut ys);
            for (b, &y) in ys.iter().enumerate() {
                counts[y as usize] += 1;
                for k in 0..3 * 64 {
                    means[y as usize][k] += px[b * 3 * 64 + k] as f64;
                }
            }
        }
        let dist: f64 = (0..3 * 64)
            .map(|k| {
                let a = means[0][k] / counts[0] as f64;
                let b = means[1][k] / counts[1] as f64;
                (a - b).powi(2)
            })
            .sum();
        assert!(dist > 1.0, "classes overlap: {dist}");
    }
}
