//! Paper-style report generation from suite artifacts.
//!
//! [`collect`] scans `<out_dir>/<suite>/` for per-cell `summary.json` /
//! `FAILED` files, [`generate`] aggregates them into the three tables the
//! paper leads with — optimizer-state **memory** (with a ratio-vs-Adam
//! column), **quality** (final loss, mean ± spread over seed repeats) and
//! **throughput** (ms/step, steps/s) — and [`write_report`] emits them as
//! Markdown (`docs/RESULTS.md`) plus a machine-readable record stream
//! (`BENCH_suite.json`, via [`crate::util::bench::JsonSink`]).
//!
//! Determinism contract: the generated Markdown is a pure function of
//! the collected records — rows are fully sorted, floats use fixed-width
//! formatting, and nothing environmental (timestamps, paths, hostnames)
//! is embedded. Re-rendering a finished suite therefore reproduces the
//! report byte-for-byte, which `make docs-check` and the golden test in
//! `rust/tests/suite.rs` pin.

use anyhow::{anyhow, Result};
use std::path::{Path, PathBuf};

use crate::models::inventory_by_name;
use crate::optim::{memory, OptKind, OptimConfig};
use crate::train::metrics;
use crate::util::bench::JsonSink;
use crate::util::fmt;
use crate::util::json::{Json, ObjBuilder};

/// One suite cell as read back from disk.
#[derive(Clone, Debug)]
pub struct CellRecord {
    /// Cell directory name under the suite dir.
    pub run: String,
    /// Workload (`synthetic:<inventory>` or artifact name).
    pub model: String,
    /// Optimizer name (`adam`, `smmf`, …).
    pub optimizer: String,
    /// Seed of this repeat.
    pub seed: u64,
    /// Steps the cell trained for.
    pub steps: u64,
    /// Loss at the first step, when finite.
    pub first_loss: Option<f64>,
    /// Loss at the last step, when finite.
    pub final_loss: Option<f64>,
    /// Mean wall-clock per training step.
    pub mean_step_ms: f64,
    /// Persistent optimizer-state bytes (identical across seeds).
    pub opt_state_bytes: u64,
    /// Trainable parameter count, when the summary records it.
    pub param_count: Option<u64>,
    /// Failure note (from the `FAILED` marker, or a summary with no
    /// finite final loss); failed cells are excluded from aggregates.
    pub failed: Option<String>,
}

/// Scan a suite directory into sorted [`CellRecord`]s. Subdirectories
/// with neither a `summary.json` nor a `FAILED` marker are ignored (they
/// are not cells). Sort order: ok cells first, then by model, paper
/// optimizer order, seed — the row order of every generated table.
pub fn collect(suite_dir: &Path) -> Result<Vec<CellRecord>> {
    let entries = std::fs::read_dir(suite_dir)
        .map_err(|e| anyhow!("reading suite dir {suite_dir:?}: {e}"))?;
    let mut dirs: Vec<PathBuf> =
        entries.filter_map(|e| e.ok()).map(|e| e.path()).filter(|p| p.is_dir()).collect();
    dirs.sort();
    let mut recs = Vec::new();
    for dir in dirs {
        let run = dir.file_name().and_then(|s| s.to_str()).unwrap_or("?").to_string();
        let failed = std::fs::read_to_string(dir.join("FAILED"))
            .ok()
            .map(|t| t.lines().next().unwrap_or("(no error recorded)").to_string());
        if let Ok(json) = metrics::read_summary(&dir) {
            let s = |k: &str| json.get(k).and_then(Json::as_str).unwrap_or("?").to_string();
            let n = |k: &str| json.get(k).and_then(Json::as_f64);
            let final_loss = n("final_loss").filter(|v| v.is_finite());
            let failed = failed.or_else(|| {
                final_loss.is_none().then(|| "summary has no finite final loss".to_string())
            });
            recs.push(CellRecord {
                run,
                model: s("model"),
                optimizer: s("optimizer"),
                seed: n("seed").unwrap_or(0.0) as u64,
                steps: n("steps").unwrap_or(0.0) as u64,
                first_loss: n("first_loss").filter(|v| v.is_finite()),
                final_loss,
                mean_step_ms: n("mean_step_ms").unwrap_or(f64::NAN),
                opt_state_bytes: n("opt_state_bytes").unwrap_or(0.0) as u64,
                param_count: n("param_count").map(|v| v as u64),
                failed,
            });
        } else {
            // No parseable summary: a FAILED marker names the error; a
            // summary file that *exists* but doesn't parse (e.g. a
            // pre-atomic-write truncation) is surfaced as a failed cell
            // rather than silently dropped from the report.
            let failed = failed.or_else(|| {
                dir.join("summary.json")
                    .exists()
                    .then(|| "unreadable summary.json (delete the cell dir to re-run)".to_string())
            });
            if failed.is_some() {
                recs.push(CellRecord {
                    run,
                    model: String::new(),
                    optimizer: String::new(),
                    seed: 0,
                    steps: 0,
                    first_loss: None,
                    final_loss: None,
                    mean_step_ms: f64::NAN,
                    opt_state_bytes: 0,
                    param_count: None,
                    failed,
                });
            }
        }
    }
    recs.sort_by(|a, b| {
        (a.failed.is_some(), &a.model, opt_rank(&a.optimizer), &a.optimizer, a.seed, &a.run).cmp(
            &(b.failed.is_some(), &b.model, opt_rank(&b.optimizer), &b.optimizer, b.seed, &b.run),
        )
    });
    Ok(recs)
}

/// Paper table ordering: baselines first, SMMF (the contribution) last.
fn opt_rank(name: &str) -> usize {
    match name {
        "sgd" => 0,
        "adam" => 1,
        "adamw" => 2,
        "adafactor" => 3,
        "sm3" => 4,
        "came" => 5,
        "smmf" => 6,
        _ => 7,
    }
}

/// One `(model, optimizer)` aggregate over its seed repeats.
struct Agg {
    model: String,
    optimizer: String,
    n: usize,
    first_mean: Option<f64>,
    final_mean: Option<f64>,
    final_spread: f64,
    ms_mean: Option<f64>,
    sps_mean: Option<f64>,
    bytes: u64,
    params: Option<u64>,
}

fn aggregate(ok: &[&CellRecord]) -> Vec<Agg> {
    let mut aggs = Vec::new();
    let mut i = 0;
    while i < ok.len() {
        let j = i + ok[i..]
            .iter()
            .take_while(|c| c.model == ok[i].model && c.optimizer == ok[i].optimizer)
            .count();
        let grp = &ok[i..j];
        let mean = |vals: &[f64]| -> Option<f64> {
            (!vals.is_empty()).then(|| vals.iter().sum::<f64>() / vals.len() as f64)
        };
        let finals: Vec<f64> = grp.iter().filter_map(|c| c.final_loss).collect();
        let firsts: Vec<f64> = grp.iter().filter_map(|c| c.first_loss).collect();
        let mss: Vec<f64> =
            grp.iter().map(|c| c.mean_step_ms).filter(|v| v.is_finite() && *v > 0.0).collect();
        let spss: Vec<f64> = mss.iter().map(|ms| 1e3 / ms).collect();
        let spread = if finals.len() >= 2 {
            let lo = finals.iter().cloned().fold(f64::INFINITY, f64::min);
            let hi = finals.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            hi - lo
        } else {
            0.0
        };
        aggs.push(Agg {
            model: grp[0].model.clone(),
            optimizer: grp[0].optimizer.clone(),
            n: grp.len(),
            first_mean: mean(&firsts),
            final_mean: mean(&finals),
            final_spread: spread,
            ms_mean: mean(&mss),
            sps_mean: mean(&spss),
            bytes: grp[0].opt_state_bytes,
            params: grp[0].param_count,
        });
        i = j;
    }
    aggs
}

/// Adam's optimizer-state bytes for a model, for the ratio column: a
/// measured adam aggregate when the suite ran one, else the analytic
/// accounting over the model's inventory (`optim::memory`), else `None`
/// (artifact-only model with no adam cell).
fn adam_reference(model: &str, aggs: &[Agg]) -> Option<u64> {
    if let Some(a) = aggs.iter().find(|a| a.model == model && a.optimizer == "adam") {
        return Some(a.bytes);
    }
    let inv = inventory_by_name(model.strip_prefix("synthetic:").unwrap_or(model))?;
    Some(memory::inventory_state_bytes(
        OptKind::Adam,
        &inv.shapes(),
        &OptimConfig::paper_defaults(OptKind::Adam),
    ))
}

fn md_escape(s: &str) -> String {
    s.replace('|', "\\|")
}

fn opt_f(v: Option<f64>, prec: usize) -> String {
    match v {
        Some(v) => format!("{v:.prec$}"),
        None => "—".into(),
    }
}

/// Render the Markdown report and the matching `BENCH_suite.json`
/// records from collected cells. Pure and deterministic — see the
/// module docs.
pub fn generate(suite: &str, cells: &[CellRecord]) -> (String, Vec<Json>) {
    let ok: Vec<&CellRecord> = cells.iter().filter(|c| c.failed.is_none()).collect();
    let failed: Vec<&CellRecord> = cells.iter().filter(|c| c.failed.is_some()).collect();
    let aggs = aggregate(&ok);

    let mut md = String::new();
    md.push_str(&format!("# Generated results — suite `{suite}`\n"));
    md.push_str("\n");
    md.push_str("Auto-generated by `repro suite` / `repro report` — do not edit by hand.\n");
    md.push_str("Cells whose `summary.json` already exists are reused on re-entry, so a\n");
    md.push_str("finished suite re-renders this file byte-for-byte; `make docs-check` pins\n");
    md.push_str("the checked-in copy to the fixture suite under\n");
    md.push_str("`rust/tests/fixtures/suite_report/`.\n");
    md.push_str("\n");
    md.push_str(&format!("Cells: {} ok, {} failed.\n", ok.len(), failed.len()));
    md.push_str("\n");

    md.push_str("## Optimizer-state memory\n");
    md.push_str("\n");
    md.push_str("Persistent optimizer-state bytes per (model, optimizer) — the paper's\n");
    md.push_str("headline claim is the `smmf` row at a small fraction of `adam` (up to\n");
    md.push_str("96% smaller, PAPER.md).\n");
    md.push_str("\n");
    md.push_str("| model | optimizer | params | opt state | bytes | vs adam |\n");
    md.push_str("|---|---|---:|---:|---:|---:|\n");
    for a in &aggs {
        let ratio = match adam_reference(&a.model, &aggs) {
            Some(adam) if adam > 0 => format!("{:.3}x", a.bytes as f64 / adam as f64),
            _ => "—".into(),
        };
        let params = match a.params {
            Some(p) => fmt::count(p),
            None => "—".into(),
        };
        md.push_str(&format!(
            "| {} | {} | {} | {} | {} | {} |\n",
            md_escape(&a.model),
            md_escape(&a.optimizer),
            params,
            fmt::bytes(a.bytes),
            a.bytes,
            ratio
        ));
    }
    md.push_str("\n");

    md.push_str("## Quality — final loss\n");
    md.push_str("\n");
    md.push_str("Mean ± spread (max − min) over the seed repeats of each cell.\n");
    md.push_str("\n");
    md.push_str("| model | optimizer | seeds | first loss | final loss |\n");
    md.push_str("|---|---|---:|---:|---:|\n");
    for a in &aggs {
        let final_cell = match a.final_mean {
            Some(m) if a.n >= 2 => format!("{m:.4} ± {:.4}", a.final_spread),
            Some(m) => format!("{m:.4}"),
            None => "—".into(),
        };
        md.push_str(&format!(
            "| {} | {} | {} | {} | {} |\n",
            md_escape(&a.model),
            md_escape(&a.optimizer),
            a.n,
            opt_f(a.first_mean, 4),
            final_cell
        ));
    }
    md.push_str("\n");

    md.push_str("## Throughput — optimizer step time\n");
    md.push_str("\n");
    md.push_str("Wall-clock per training step, averaged over seeds. Machine-dependent:\n");
    md.push_str("regenerate locally before comparing numbers across machines.\n");
    md.push_str("\n");
    md.push_str("| model | optimizer | ms/step | steps/s |\n");
    md.push_str("|---|---|---:|---:|\n");
    for a in &aggs {
        md.push_str(&format!(
            "| {} | {} | {} | {} |\n",
            md_escape(&a.model),
            md_escape(&a.optimizer),
            opt_f(a.ms_mean, 2),
            opt_f(a.sps_mean, 0)
        ));
    }
    md.push_str("\n");

    md.push_str("## Failed cells\n");
    md.push_str("\n");
    if failed.is_empty() {
        md.push_str("(none)\n");
    } else {
        md.push_str("| run | error |\n");
        md.push_str("|---|---|\n");
        for c in &failed {
            md.push_str(&format!(
                "| {} | {} |\n",
                md_escape(&c.run),
                md_escape(c.failed.as_deref().unwrap_or("?"))
            ));
        }
    }

    let mut records = Vec::new();
    for c in &ok {
        records.push(
            ObjBuilder::new()
                .str("record", "cell")
                .str("run", &c.run)
                .str("model", &c.model)
                .str("optimizer", &c.optimizer)
                .num("seed", c.seed as f64)
                .num("steps", c.steps as f64)
                .num("first_loss", c.first_loss.unwrap_or(f64::NAN))
                .num("final_loss", c.final_loss.unwrap_or(f64::NAN))
                .num("mean_step_ms", c.mean_step_ms)
                .num("opt_state_bytes", c.opt_state_bytes as f64)
                .build(),
        );
    }
    for a in &aggs {
        let mut b = ObjBuilder::new()
            .str("record", "aggregate")
            .str("model", &a.model)
            .str("optimizer", &a.optimizer)
            .num("seeds", a.n as f64)
            .num("final_loss_mean", a.final_mean.unwrap_or(f64::NAN))
            .num("final_loss_spread", a.final_spread)
            .num("mean_step_ms", a.ms_mean.unwrap_or(f64::NAN))
            .num("steps_per_s", a.sps_mean.unwrap_or(f64::NAN))
            .num("opt_state_bytes", a.bytes as f64);
        if let Some(adam) = adam_reference(&a.model, &aggs).filter(|&x| x > 0) {
            b = b.num("vs_adam", a.bytes as f64 / adam as f64);
        }
        records.push(b.build());
    }
    for c in &failed {
        records.push(
            ObjBuilder::new()
                .str("record", "failed")
                .str("run", &c.run)
                .str("error", c.failed.as_deref().unwrap_or("?"))
                .build(),
        );
    }
    (md, records)
}

/// Collect + generate + write: `docs_path` gets the Markdown,
/// `bench_path` the JSON record stream. Parent directories are created.
/// Returns the number of cells that went into the report.
pub fn write_report(
    suite: &str,
    suite_dir: &Path,
    docs_path: &Path,
    bench_path: &Path,
) -> Result<usize> {
    let cells = collect(suite_dir)?;
    let (md, records) = generate(suite, &cells);
    if let Some(parent) = docs_path.parent().filter(|p| !p.as_os_str().is_empty()) {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(docs_path, &md).map_err(|e| anyhow!("writing {docs_path:?}: {e}"))?;
    if let Some(parent) = bench_path.parent().filter(|p| !p.as_os_str().is_empty()) {
        std::fs::create_dir_all(parent)?;
    }
    let mut sink = JsonSink::new(&format!("suite:{suite}"), bench_path);
    for r in records {
        sink.push(r);
    }
    sink.write().map_err(|e| anyhow!("writing {bench_path:?}: {e}"))?;
    Ok(cells.len())
}
