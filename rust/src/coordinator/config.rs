//! Experiment configuration: TOML file + CLI overrides.

use anyhow::{anyhow, Result};
use std::path::Path;

use crate::optim::group::{GroupPolicy, GroupedConfig, ParamRole, StatePolicy};
use crate::optim::{OptKind, OptimConfig, WeightDecayMode};
use crate::optim::schedule::LrSchedule;
use crate::util::cli::Args;
use crate::util::toml::TomlDoc;

/// Everything a training experiment needs.
/// `PartialEq` backs the `SMMFCELL` wire round-trip guard: before
/// shipping a cell, the remote dispatcher checks
/// `from_toml_str(to_toml(cfg)) == cfg` and fails the cell on a
/// mismatch (see `docs/SUITE_WIRE.md`).
#[derive(Clone, Debug, PartialEq)]
pub struct ExperimentConfig {
    pub name: String,
    pub artifact: String,
    pub optimizer: OptKind,
    pub optim: OptimConfig,
    /// Param-group matcher blocks (`[[optimizer.group]]` / `--group`),
    /// resolved against the inventory at build time (first match wins).
    pub groups: Vec<GroupPolicy>,
    pub steps: u64,
    pub seed: u64,
    pub log_every: u64,
    pub out_dir: String,
    pub schedule: LrSchedule,
    pub workers: usize,
    /// Resume from this `SMMFCKPT` checkpoint before training
    /// (`--resume <path>` / `[train] resume = "..."`).
    pub resume: Option<String>,
    /// Write `runs/<name>/checkpoint.bin` every N steps and at the end
    /// (0 = checkpointing off; `--save-every N` / `[train] save_every`).
    pub save_every: u64,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        Self {
            name: "run".into(),
            artifact: "lm_tiny_grads".into(),
            optimizer: OptKind::Smmf,
            optim: OptimConfig::paper_defaults(OptKind::Smmf),
            groups: Vec::new(),
            steps: 200,
            seed: 0,
            log_every: 10,
            out_dir: "runs".into(),
            schedule: LrSchedule::Constant,
            workers: 1,
            resume: None,
            save_every: 0,
        }
    }
}

impl ExperimentConfig {
    /// Load from a TOML file (all keys optional).
    pub fn from_toml(path: &Path) -> Result<ExperimentConfig> {
        let text = std::fs::read_to_string(path)?;
        let doc = TomlDoc::parse(&text).map_err(|e| anyhow!("{path:?}: {e}"))?;
        let mut cfg = ExperimentConfig::default();
        cfg.apply_toml(&doc)?;
        Ok(cfg)
    }

    pub fn apply_toml(&mut self, doc: &TomlDoc) -> Result<()> {
        self.name = doc.str_or("name", &self.name).to_string();
        self.artifact = doc.str_or("artifact", &self.artifact).to_string();
        if let Some(k) = doc.get("optimizer.kind").and_then(|v| v.as_str()) {
            self.set_optimizer(k)?;
        }
        // Train-loop knobs are accepted both at the top level (the
        // historical spelling) and grouped under `[train]` — whichever
        // grouping the user picks, no key is silently ignored. The
        // `[train]` spelling wins when both are present.
        let i64_either = |key: &str, current: i64| -> i64 {
            doc.i64_or(&format!("train.{key}"), doc.i64_or(key, current))
        };
        self.steps = i64_either("steps", self.steps as i64) as u64;
        self.seed = i64_either("seed", self.seed as i64) as u64;
        // Clamped to >= 1: the training loops take `step % log_every`,
        // and a panicking cell would tear down a whole suite pool.
        self.log_every = i64_either("log_every", self.log_every as i64).max(1) as u64;
        self.workers = i64_either("workers", self.workers as i64) as usize;
        self.save_every = i64_either("save_every", self.save_every as i64).max(0) as u64;
        self.out_dir = doc
            .get("train.out_dir")
            .and_then(|v| v.as_str())
            .unwrap_or_else(|| doc.str_or("out_dir", &self.out_dir))
            .to_string();
        if let Some(path) =
            doc.get("train.resume").or_else(|| doc.get("resume")).and_then(|v| v.as_str())
        {
            self.resume = Some(path.to_string());
        }
        // `[[optimizer.group]]` matcher blocks (name-glob / role
        // selectors + per-group overrides). When present they replace the
        // current group list, so a TOML file fully specifies its groups.
        let n_groups = doc.array_len("optimizer.group");
        if n_groups > 0 {
            let mut groups = Vec::with_capacity(n_groups);
            for i in 0..n_groups {
                let pre = format!("optimizer.group.{i}");
                let mut g = GroupPolicy {
                    name: doc.str_or(&format!("{pre}.name"), &format!("group{i}")).to_string(),
                    ..GroupPolicy::default()
                };
                if let Some(roles) = doc.str_list(&format!("{pre}.match_role")) {
                    for r in roles {
                        let role = ParamRole::parse(&r)
                            .ok_or_else(|| anyhow!("group {i}: unknown role {r}"))?;
                        g.match_roles.push(role);
                    }
                }
                if let Some(names) = doc.str_list(&format!("{pre}.match_name")) {
                    g.match_names = names;
                }
                g.lr_scale = doc.f64_or(&format!("{pre}.lr_scale"), g.lr_scale as f64) as f32;
                if let Some(wd) = doc.get(&format!("{pre}.weight_decay")).and_then(|v| v.as_f64())
                {
                    g.weight_decay = Some(wd as f32);
                }
                g.frozen = doc.bool_or(&format!("{pre}.frozen"), g.frozen);
                if let Some(s) = doc.get(&format!("{pre}.state")).and_then(|v| v.as_str()) {
                    g.state = StatePolicy::parse(s)
                        .ok_or_else(|| anyhow!("group {}: unknown state policy {s}", g.name))?;
                }
                groups.push(g);
            }
            self.groups = groups;
        }
        let o = &mut self.optim;
        o.lr = doc.f64_or("optimizer.lr", o.lr as f64) as f32;
        o.beta1 = doc.f64_or("optimizer.beta1", o.beta1 as f64) as f32;
        o.beta2 = doc.f64_or("optimizer.beta2", o.beta2 as f64) as f32;
        o.weight_decay = doc.f64_or("optimizer.weight_decay", o.weight_decay as f64) as f32;
        o.decay_rate = doc.f64_or("optimizer.decay_rate", o.decay_rate as f64) as f32;
        o.growth_rate = doc.f64_or("optimizer.growth_rate", o.growth_rate as f64) as f32;
        o.vector_reshape = doc.bool_or("optimizer.vector_reshape", o.vector_reshape);
        // Paper defaults disable Adam/AdamW bias correction (pre-training
        // configs); this key opts back in per run.
        o.bias_correction = doc.bool_or("optimizer.bias_correction", o.bias_correction);
        // Parallel step engine worker threads (>= 1; 1 = serial).
        o.threads = (doc.i64_or("optimizer.threads", o.threads as i64).max(1)) as usize;
        if let Some(mode) = doc.get("optimizer.weight_decay_mode").and_then(|v| v.as_str()) {
            o.weight_decay_mode = match mode {
                "adam" => WeightDecayMode::Adam,
                "adamw" => WeightDecayMode::AdamW,
                other => return Err(anyhow!("bad weight_decay_mode {other}")),
            };
        }
        match doc.str_or("schedule.kind", "constant") {
            "constant" => self.schedule = LrSchedule::Constant,
            "warmup" => {
                self.schedule =
                    LrSchedule::Warmup { warmup: doc.i64_or("schedule.warmup", 100) as u64 }
            }
            "linear" => {
                self.schedule = LrSchedule::Linear {
                    warmup: doc.i64_or("schedule.warmup", 100) as u64,
                    total: doc.i64_or("schedule.total", self.steps as i64) as u64,
                }
            }
            "invsqrt" => {
                self.schedule =
                    LrSchedule::InvSqrt { warmup: doc.i64_or("schedule.warmup", 100) as u64 }
            }
            other => return Err(anyhow!("bad schedule.kind {other}")),
        }
        Ok(())
    }

    /// Apply `--key value` CLI overrides on top.
    pub fn apply_args(&mut self, args: &Args) -> Result<()> {
        if let Some(k) = args.opt("optimizer") {
            self.set_optimizer(k)?;
        }
        if let Some(a) = args.opt("artifact") {
            self.artifact = a.to_string();
        }
        if let Some(n) = args.opt("name") {
            self.name = n.to_string();
        }
        self.steps = args.u64_or("steps", self.steps);
        self.seed = args.u64_or("seed", self.seed);
        self.log_every = args.u64_or("log-every", self.log_every).max(1);
        self.workers = args.positive_usize_or("workers", self.workers);
        self.out_dir = args.str_or("out-dir", &self.out_dir);
        if let Some(path) = args.opt("resume") {
            self.resume = Some(path.to_string());
        }
        self.save_every = args.u64_or("save-every", self.save_every);
        // `--group "name=no_decay,role=bias|norm,wd=0; match=*emb*,lr_scale=0.5"`
        // replaces any TOML-defined groups (CLI wins, like every other knob).
        if let Some(specs) = args.opt("group") {
            self.groups = GroupPolicy::parse_cli_list(specs).map_err(|e| anyhow!("--group: {e}"))?;
        }
        self.optim.threads = args.positive_usize_or("threads", self.optim.threads);
        self.optim.lr = args.f64_or("lr", self.optim.lr as f64) as f32;
        self.optim.weight_decay = args.f64_or("weight-decay", self.optim.weight_decay as f64) as f32;
        self.optim.decay_rate = args.f64_or("decay-rate", self.optim.decay_rate as f64) as f32;
        if let Some(v) = args.opt("bias-correction") {
            self.optim.bias_correction = match v {
                "true" | "1" | "on" => true,
                "false" | "0" | "off" => false,
                other => return Err(anyhow!("bad --bias-correction {other} (true/false)")),
            };
        }
        Ok(())
    }

    /// The grouped optimizer config this experiment resolves to.
    pub fn grouped(&self) -> GroupedConfig {
        GroupedConfig { base: self.optim.clone(), groups: self.groups.clone() }
    }

    /// Switch the target optimizer, re-deriving its paper defaults
    /// (Appendix L β/ε tables) while preserving the recipe-shared knobs:
    /// lr, γ (`decay_rate`), weight decay + coupling mode, and engine
    /// threads. This is the substitution rule the figure comparisons and
    /// the suite expander share — "same workload recipe, different
    /// optimizer".
    pub fn retarget_optimizer(&mut self, kind: OptKind) {
        let o = self.optim.clone();
        self.optimizer = kind;
        self.optim = OptimConfig::paper_defaults(kind);
        self.optim.lr = o.lr;
        self.optim.decay_rate = o.decay_rate;
        self.optim.weight_decay = o.weight_decay;
        self.optim.weight_decay_mode = o.weight_decay_mode;
        self.optim.threads = o.threads;
    }

    fn set_optimizer(&mut self, kind: &str) -> Result<()> {
        let k = OptKind::parse(kind).ok_or_else(|| anyhow!("unknown optimizer {kind}"))?;
        // Re-derive paper defaults for the new kind, preserving the
        // recipe-independent knobs (lr, engine threads).
        let lr = self.optim.lr;
        let threads = self.optim.threads;
        self.optimizer = k;
        self.optim = OptimConfig::paper_defaults(k);
        self.optim.lr = lr;
        self.optim.threads = threads;
        Ok(())
    }

    /// Render this config as canonical TOML for the `SMMFCELL` wire
    /// (`docs/SUITE_WIRE.md`): a `repro worker` daemon rebuilds the cell
    /// config by feeding this text through [`ExperimentConfig::from_toml_str`].
    ///
    /// The renderer emits exactly the TOML-settable key set. Fields
    /// outside it (per-optimizer ε/β tables, SMMF ablation knobs) are
    /// re-derived from `optimizer.kind` paper defaults on both sides —
    /// the same rule [`ExperimentConfig::apply_toml`] and
    /// [`ExperimentConfig::retarget_optimizer`] follow — so every config
    /// a suite can expand round-trips losslessly (the dispatcher
    /// re-checks this per cell before shipping it, failing the cell on
    /// a mismatch). Errors on values the TOML
    /// subset cannot carry (quotes/newlines in strings, non-finite
    /// floats, schedules `apply_toml` cannot parse back).
    pub fn to_toml(&self) -> Result<String> {
        use std::fmt::Write as _;
        fn st(out: &mut String, key: &str, v: &str) -> Result<()> {
            if v.contains('"') || v.contains('\n') {
                return Err(anyhow!("cannot render {key} = {v:?} (quotes/newlines unsupported)"));
            }
            writeln!(out, "{key} = \"{v}\"").ok();
            Ok(())
        }
        fn fl(out: &mut String, key: &str, v: f32) -> Result<()> {
            if !v.is_finite() {
                return Err(anyhow!("cannot render {key} = {v} (non-finite)"));
            }
            // f32 -> f64 is exact and f64's shortest Display round-trips,
            // so `parse::<f64>() as f32` recovers the exact bits.
            writeln!(out, "{key} = {}", v as f64).ok();
            Ok(())
        }
        let mut out = String::new();
        st(&mut out, "name", &self.name)?;
        st(&mut out, "artifact", &self.artifact)?;
        out.push_str("[optimizer]\n");
        st(&mut out, "kind", self.optimizer.name())?;
        let o = &self.optim;
        fl(&mut out, "lr", o.lr)?;
        fl(&mut out, "beta1", o.beta1)?;
        fl(&mut out, "beta2", o.beta2)?;
        fl(&mut out, "weight_decay", o.weight_decay)?;
        fl(&mut out, "decay_rate", o.decay_rate)?;
        fl(&mut out, "growth_rate", o.growth_rate)?;
        writeln!(out, "vector_reshape = {}", o.vector_reshape).ok();
        writeln!(out, "bias_correction = {}", o.bias_correction).ok();
        writeln!(out, "threads = {}", o.threads.max(1)).ok();
        let mode = match o.weight_decay_mode {
            WeightDecayMode::Adam => "adam",
            WeightDecayMode::AdamW => "adamw",
        };
        st(&mut out, "weight_decay_mode", mode)?;
        for g in &self.groups {
            out.push_str("[[optimizer.group]]\n");
            st(&mut out, "name", &g.name)?;
            if !g.match_roles.is_empty() {
                let roles: Vec<String> =
                    g.match_roles.iter().map(|r| format!("\"{}\"", r.name())).collect();
                writeln!(out, "match_role = [{}]", roles.join(", ")).ok();
            }
            if !g.match_names.is_empty() {
                let mut names = Vec::with_capacity(g.match_names.len());
                for n in &g.match_names {
                    if n.contains('"') || n.contains('\n') {
                        return Err(anyhow!("cannot render match_name {n:?}"));
                    }
                    names.push(format!("\"{n}\""));
                }
                writeln!(out, "match_name = [{}]", names.join(", ")).ok();
            }
            fl(&mut out, "lr_scale", g.lr_scale)?;
            if let Some(wd) = g.weight_decay {
                fl(&mut out, "weight_decay", wd)?;
            }
            writeln!(out, "frozen = {}", g.frozen).ok();
            st(&mut out, "state", g.state.name())?;
        }
        out.push_str("[train]\n");
        writeln!(out, "steps = {}", self.steps).ok();
        writeln!(out, "seed = {}", self.seed).ok();
        writeln!(out, "log_every = {}", self.log_every.max(1)).ok();
        writeln!(out, "workers = {}", self.workers).ok();
        writeln!(out, "save_every = {}", self.save_every).ok();
        st(&mut out, "out_dir", &self.out_dir)?;
        if let Some(resume) = &self.resume {
            st(&mut out, "resume", resume)?;
        }
        out.push_str("[schedule]\n");
        match self.schedule {
            LrSchedule::Constant => st(&mut out, "kind", "constant")?,
            LrSchedule::Warmup { warmup } => {
                st(&mut out, "kind", "warmup")?;
                writeln!(out, "warmup = {warmup}").ok();
            }
            LrSchedule::Linear { warmup, total } => {
                st(&mut out, "kind", "linear")?;
                writeln!(out, "warmup = {warmup}").ok();
                writeln!(out, "total = {total}").ok();
            }
            LrSchedule::InvSqrt { warmup } => {
                st(&mut out, "kind", "invsqrt")?;
                writeln!(out, "warmup = {warmup}").ok();
            }
            // Not expressible in the TOML schedule section (and not
            // reachable from a suite file), so not wire-shippable.
            ref other => return Err(anyhow!("cannot render schedule {other:?} as TOML")),
        }
        Ok(out)
    }

    /// Parse a config from TOML text (the worker side of the `SMMFCELL`
    /// wire; also exactly what [`ExperimentConfig::from_toml`] does for
    /// a file).
    pub fn from_toml_str(text: &str) -> Result<ExperimentConfig> {
        let doc = TomlDoc::parse(text).map_err(|e| anyhow!("cell config: {e}"))?;
        let mut cfg = ExperimentConfig::default();
        cfg.apply_toml(&doc)?;
        Ok(cfg)
    }
}

// ---------------------------------------------------------------------------
// Experiment suites: declarative optimizer × model sweeps
// ---------------------------------------------------------------------------

/// Where a suite schedules its cells: a local thread-pool width plus
/// zero or more remote `repro worker` addresses. Spelled in TOML/CLI as
/// either a plain integer (`workers = 4`, the historical local pool) or
/// a spec string:
///
/// * `"local:4"` — local thread pool, width 4
/// * `"remote:host:7131,host:7132"` — remote workers only
/// * `"local:2,remote:host:7131"` — mixed: local lanes drain the same
///   cell queue as the remote dispatcher
///
/// Validation mirrors the `count_or` rule: zero/negative widths and
/// malformed entries are errors, never clamps.
#[derive(Clone, Debug, PartialEq)]
pub struct WorkerSpec {
    /// Local worker-thread count (0 = no local lanes; only valid when
    /// `remote` is non-empty).
    pub local: usize,
    /// Remote `repro worker` addresses (`host:port`), dispatch order.
    pub remote: Vec<String>,
}

impl WorkerSpec {
    /// A purely local pool of `n` threads.
    pub fn local(n: usize) -> WorkerSpec {
        WorkerSpec { local: n, remote: Vec::new() }
    }

    /// No remote workers — schedule on the in-process `fan_out` pool.
    pub fn is_local_only(&self) -> bool {
        self.remote.is_empty()
    }

    /// Human-readable summary for suite log lines.
    pub fn describe(&self) -> String {
        match (self.local, self.remote.len()) {
            (n, 0) => format!("{n} local worker(s)"),
            (0, r) => format!("{r} remote worker(s)"),
            (n, r) => format!("{r} remote + {n} local worker(s)"),
        }
    }

    /// Parse a worker spec: a plain integer, or comma-separated
    /// `local:N` / `remote:HOST:PORT` entries. After a `remote:` entry,
    /// bare `HOST:PORT` tokens extend the remote list, so
    /// `"remote:a:1,b:2"` names two workers.
    pub fn parse(s: &str) -> std::result::Result<WorkerSpec, String> {
        let s = s.trim();
        if let Ok(n) = s.parse::<i64>() {
            if n >= 1 {
                return Ok(WorkerSpec::local(n as usize));
            }
            return Err("workers must be an integer >= 1".into());
        }
        let addr = |tok: &str| -> std::result::Result<String, String> {
            let tok = tok.trim();
            if tok.is_empty() || !tok.contains(':') {
                return Err(format!("bad remote worker address {tok:?} (expected HOST:PORT)"));
            }
            Ok(tok.to_string())
        };
        let (mut local, mut local_seen) = (0usize, false);
        let mut remote: Vec<String> = Vec::new();
        let mut in_remote_list = false;
        for tok in s.split(',') {
            let tok = tok.trim();
            if let Some(n) = tok.strip_prefix("local:") {
                if local_seen {
                    return Err(format!("duplicate local: entry in {s:?}"));
                }
                local_seen = true;
                in_remote_list = false;
                match n.trim().parse::<i64>() {
                    Ok(n) if n >= 1 => local = n as usize,
                    _ => return Err("local worker count must be an integer >= 1".into()),
                }
            } else if let Some(a) = tok.strip_prefix("remote:") {
                in_remote_list = true;
                remote.push(addr(a)?);
            } else if in_remote_list {
                remote.push(addr(tok)?);
            } else {
                return Err(format!(
                    "bad workers entry {tok:?} (expected an integer >= 1, local:N, or remote:HOST:PORT)"
                ));
            }
        }
        for (i, a) in remote.iter().enumerate() {
            if remote[..i].contains(a) {
                return Err(format!("duplicate remote worker address {a:?}"));
            }
        }
        if remote.is_empty() && local == 0 {
            return Err("workers spec names no workers (integer >= 1, local:N, or remote:HOST:PORT)".into());
        }
        Ok(WorkerSpec { local, remote })
    }
}

/// One `[[suite.run]]` block before expansion: a cartesian
/// `optimizers × models × seeds` sweep sharing per-block overrides.
/// `models` entries are AOT artifact names (`lm_tiny_grads`, …) or
/// `synthetic:<inventory>` for the artifact-free quadratic workload.
#[derive(Clone, Debug, Default)]
pub struct SuiteRunBlock {
    /// Optional block label, prefixed onto every cell's run name
    /// (required to disambiguate blocks that expand to the same cells).
    pub label: String,
    /// Optimizer kinds to sweep (required, non-empty).
    pub optimizers: Vec<OptKind>,
    /// Workloads to sweep (required, non-empty).
    pub models: Vec<String>,
    /// Per-block seed list; `None` inherits `[suite] seeds`.
    pub seeds: Option<Vec<u64>>,
    /// Per-block overrides on top of the suite's base config.
    pub steps: Option<u64>,
    /// Base learning rate override.
    pub lr: Option<f64>,
    /// Weight-decay override.
    pub weight_decay: Option<f64>,
    /// γ (2nd-moment schedule exponent) override.
    pub decay_rate: Option<f64>,
    /// Parallel step-engine threads override.
    pub threads: Option<usize>,
    /// Metrics cadence override.
    pub log_every: Option<u64>,
    /// Checkpoint cadence override (artifact workloads only).
    pub save_every: Option<u64>,
}

/// A parsed suite file: `[suite]` header + shared base config (the
/// regular `[optimizer]` / `[train]` / `[schedule]` / `[[optimizer.group]]`
/// sections) + `[[suite.run]]` sweep blocks. See
/// `rust/tests/suite_smoke.toml` and the README's "Reproduce the paper
/// tables" quickstart.
#[derive(Clone, Debug)]
pub struct SuiteConfig {
    /// Suite name — artifacts land under `<out_dir>/<name>/<run>/`.
    pub name: String,
    /// Root artifacts directory (default `runs`).
    pub out_dir: String,
    /// Default seed list for repeat-aggregation (default `[0]`).
    pub seeds: Vec<u64>,
    /// Where cells are scheduled: a local pool width or a
    /// local/remote [`WorkerSpec`] (default: 1 local worker).
    pub workers: WorkerSpec,
    /// Shared base experiment config every cell starts from.
    pub base: ExperimentConfig,
    /// The sweep blocks, in file order.
    pub runs: Vec<SuiteRunBlock>,
}

/// One expanded suite cell: a fully resolved experiment plus the
/// bookkeeping the scheduler and report generator need.
#[derive(Clone, Debug)]
pub struct SuiteCell {
    /// Cell directory name under `<out_dir>/<suite>/`.
    pub run: String,
    /// The workload as written in the suite file.
    pub model: String,
    /// Optimizer under test.
    pub optimizer: OptKind,
    /// Data/init seed for this repeat.
    pub seed: u64,
    /// The resolved per-cell experiment config
    /// (`cfg.name = "<suite>/<run>"`, `cfg.out_dir = <out_dir>`).
    pub cfg: ExperimentConfig,
}

const SUITE_KEYS: &[&str] = &["name", "out_dir", "seeds", "workers"];
const RUN_KEYS: &[&str] = &[
    "label", "optimizers", "models", "seeds", "steps", "lr", "weight_decay", "decay_rate",
    "threads", "log_every", "save_every",
];

impl SuiteConfig {
    /// Load and validate a suite file; the file stem is the default
    /// suite name.
    pub fn from_toml(path: &Path) -> Result<SuiteConfig> {
        let text =
            std::fs::read_to_string(path).map_err(|e| anyhow!("reading {path:?}: {e}"))?;
        let stem = path.file_stem().and_then(|s| s.to_str()).unwrap_or("suite");
        Self::parse(&text, stem).map_err(|e| anyhow!("{path:?}: {e}"))
    }

    /// Parse suite TOML. Unknown `[suite]` / `[[suite.run]]` keys are
    /// rejected (typos must not silently drop a sweep dimension); the
    /// base sections reuse [`ExperimentConfig::apply_toml`] verbatim.
    pub fn parse(text: &str, default_name: &str) -> Result<SuiteConfig> {
        let doc = TomlDoc::parse(text).map_err(|e| anyhow!(e))?;
        let mut base = ExperimentConfig::default();
        base.apply_toml(&doc)?;
        for key in doc.keys_under("suite") {
            if key.starts_with("run.") {
                continue; // validated per block below
            }
            if !SUITE_KEYS.contains(&key) {
                return Err(anyhow!("[suite]: unknown key {key} (known: {})", SUITE_KEYS.join(", ")));
            }
        }
        let name = doc.str_or("suite.name", default_name).to_string();
        if name.is_empty() || name.contains('/') || name.contains("..") {
            return Err(anyhow!("bad suite name {name:?} (no slashes or '..')"));
        }
        let seeds = match doc.get("suite.seeds") {
            None => vec![0],
            Some(_) => parse_seed_list(&doc, "suite.seeds")
                .ok_or_else(|| anyhow!("[suite]: seeds must be a non-empty list of integers >= 0"))?,
        };
        let n = doc.array_len("suite.run");
        if n == 0 {
            return Err(anyhow!("suite file has no [[suite.run]] blocks"));
        }
        let mut runs = Vec::with_capacity(n);
        for i in 0..n {
            let pre = format!("suite.run.{i}");
            for key in doc.keys_under(&pre) {
                if !RUN_KEYS.contains(&key) {
                    return Err(anyhow!(
                        "[[suite.run]] #{i}: unknown key {key} (known: {})",
                        RUN_KEYS.join(", ")
                    ));
                }
            }
            let take_i64 = |k: &str| -> Result<Option<i64>> {
                match doc.get(&format!("{pre}.{k}")) {
                    None => Ok(None),
                    Some(v) => match v.as_i64() {
                        Some(x) => Ok(Some(x)),
                        None => Err(anyhow!("[[suite.run]] #{i}: {k} must be an integer")),
                    },
                }
            };
            let take_f64 = |k: &str| -> Result<Option<f64>> {
                match doc.get(&format!("{pre}.{k}")) {
                    None => Ok(None),
                    Some(v) => match v.as_f64() {
                        Some(x) => Ok(Some(x)),
                        None => Err(anyhow!("[[suite.run]] #{i}: {k} must be a number")),
                    },
                }
            };
            let opt_names = doc
                .str_list(&format!("{pre}.optimizers"))
                .ok_or_else(|| anyhow!("[[suite.run]] #{i}: missing optimizers = [\"…\"]"))?;
            let mut optimizers = Vec::with_capacity(opt_names.len());
            for o in &opt_names {
                optimizers.push(
                    OptKind::parse(o)
                        .ok_or_else(|| anyhow!("[[suite.run]] #{i}: unknown optimizer {o}"))?,
                );
            }
            if optimizers.is_empty() {
                return Err(anyhow!("[[suite.run]] #{i}: optimizers must be non-empty"));
            }
            let models = doc
                .str_list(&format!("{pre}.models"))
                .ok_or_else(|| anyhow!("[[suite.run]] #{i}: missing models = [\"…\"]"))?;
            if models.is_empty() {
                return Err(anyhow!("[[suite.run]] #{i}: models must be non-empty"));
            }
            let seeds = match doc.get(&format!("{pre}.seeds")) {
                None => None,
                Some(_) => Some(parse_seed_list(&doc, &format!("{pre}.seeds")).ok_or_else(
                    || anyhow!("[[suite.run]] #{i}: seeds must be a non-empty list of integers >= 0"),
                )?),
            };
            let steps = take_i64("steps")?;
            if matches!(steps, Some(s) if s <= 0) {
                return Err(anyhow!("[[suite.run]] #{i}: steps must be > 0"));
            }
            let label = doc.str_or(&format!("{pre}.label"), "").to_string();
            if !label.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-') {
                return Err(anyhow!("[[suite.run]] #{i}: label must be [A-Za-z0-9_-]"));
            }
            runs.push(SuiteRunBlock {
                label,
                optimizers,
                models,
                seeds,
                steps: steps.map(|s| s as u64),
                lr: take_f64("lr")?,
                weight_decay: take_f64("weight_decay")?,
                decay_rate: take_f64("decay_rate")?,
                threads: take_i64("threads")?.map(|t| (t.max(1)) as usize),
                log_every: take_i64("log_every")?.map(|v| v.max(1) as u64),
                save_every: take_i64("save_every")?.map(|v| v.max(0) as u64),
            });
        }
        // Worker-count knobs are validated (not silently clamped) at the
        // config layer: a zero- or negative-width pool is a config
        // mistake the user must see, mirroring the `log_every` hardening.
        // Integer spellings keep the historical local-pool meaning;
        // string spellings name local/remote backends (see WorkerSpec).
        let workers = match doc.get("suite.workers") {
            Some(v) if v.as_str().is_some() => WorkerSpec::parse(v.as_str().unwrap())
                .map_err(|e| anyhow!("[suite]: workers: {e}"))?,
            _ => WorkerSpec::local(
                doc.count_or("suite.workers", 1).map_err(|e| anyhow!("[suite]: {e}"))?,
            ),
        };
        let out_dir = doc.str_or("suite.out_dir", &base.out_dir).to_string();
        Ok(SuiteConfig { name, out_dir, seeds, workers, base, runs })
    }

    /// Expand every block into its cartesian `optimizers × models ×
    /// seeds` cell list. Cell configs re-derive per-optimizer paper
    /// defaults via [`ExperimentConfig::retarget_optimizer`], then apply
    /// the block overrides; duplicate run names across blocks are an
    /// error (add `label` to disambiguate).
    pub fn expand(&self) -> Result<Vec<SuiteCell>> {
        let mut cells = Vec::new();
        let mut names = std::collections::BTreeSet::new();
        for (bi, block) in self.runs.iter().enumerate() {
            let seeds = block.seeds.as_ref().unwrap_or(&self.seeds);
            for model in &block.models {
                for &opt in &block.optimizers {
                    for &seed in seeds {
                        let mut cfg = self.base.clone();
                        cfg.retarget_optimizer(opt);
                        cfg.artifact = model.clone();
                        cfg.seed = seed;
                        cfg.resume = None;
                        if let Some(v) = block.steps {
                            cfg.steps = v;
                        }
                        if let Some(v) = block.lr {
                            cfg.optim.lr = v as f32;
                        }
                        if let Some(v) = block.weight_decay {
                            cfg.optim.weight_decay = v as f32;
                        }
                        if let Some(v) = block.decay_rate {
                            cfg.optim.decay_rate = v as f32;
                        }
                        if let Some(v) = block.threads {
                            cfg.optim.threads = v;
                        }
                        if let Some(v) = block.log_every {
                            cfg.log_every = v;
                        }
                        if let Some(v) = block.save_every {
                            cfg.save_every = v;
                        }
                        let run = cell_run_name(&block.label, model, opt, seed);
                        if !names.insert(run.clone()) {
                            return Err(anyhow!(
                                "suite {}: [[suite.run]] #{bi} re-expands cell {run} — \
                                 add a distinct `label` to overlapping blocks",
                                self.name
                            ));
                        }
                        cfg.name = format!("{}/{run}", self.name);
                        cfg.out_dir = self.out_dir.clone();
                        cells.push(SuiteCell {
                            run,
                            model: model.clone(),
                            optimizer: opt,
                            seed,
                            cfg,
                        });
                    }
                }
            }
        }
        Ok(cells)
    }
}

fn parse_seed_list(doc: &TomlDoc, key: &str) -> Option<Vec<u64>> {
    let raw = doc.i64_list(key)?;
    if raw.is_empty() || raw.iter().any(|&s| s < 0) {
        return None;
    }
    Some(raw.into_iter().map(|s| s as u64).collect())
}

/// `<label->?<model>-<optimizer>-s<seed>` with the `synthetic:` prefix
/// stripped and path-hostile characters sanitized.
fn cell_run_name(label: &str, model: &str, opt: OptKind, seed: u64) -> String {
    let model = model.strip_prefix("synthetic:").unwrap_or(model);
    let sanitized: String = model
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '_' { c } else { '-' })
        .collect();
    if label.is_empty() {
        format!("{sanitized}-{}-s{seed}", opt.name())
    } else {
        format!("{label}-{sanitized}-{}-s{seed}", opt.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn toml_then_cli_overrides() {
        let doc = TomlDoc::parse(
            "name = \"fig2\"\nsteps = 400\n[optimizer]\nkind = \"came\"\nlr = 0.002\n[schedule]\nkind = \"warmup\"\nwarmup = 50",
        )
        .unwrap();
        let mut cfg = ExperimentConfig::default();
        cfg.apply_toml(&doc).unwrap();
        assert_eq!(cfg.optimizer, OptKind::Came);
        assert_eq!(cfg.steps, 400);
        assert!((cfg.optim.lr - 0.002).abs() < 1e-9);
        assert_eq!(cfg.schedule, LrSchedule::Warmup { warmup: 50 });
        // CAME paper defaults picked up
        assert!((cfg.optim.eps2 - 1e-16).abs() < 1e-20);

        let args = Args::parse(
            ["--optimizer", "smmf", "--steps", "10", "--lr", "0.01"].iter().map(|s| s.to_string()),
        );
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.optimizer, OptKind::Smmf);
        assert_eq!(cfg.steps, 10);
        assert!((cfg.optim.lr - 0.01).abs() < 1e-9);
    }

    #[test]
    fn threads_plumb_through_toml_and_cli() {
        let doc = TomlDoc::parse("[optimizer]\nkind = \"smmf\"\nthreads = 4").unwrap();
        let mut cfg = ExperimentConfig::default();
        cfg.apply_toml(&doc).unwrap();
        assert_eq!(cfg.optim.threads, 4);
        // Switching the optimizer on the CLI must not reset threads...
        let args = Args::parse(["--optimizer", "adam"].iter().map(|s| s.to_string()));
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.optimizer, OptKind::Adam);
        assert_eq!(cfg.optim.threads, 4);
        // ...and --threads overrides (clamped to >= 1).
        let args = Args::parse(["--threads", "8"].iter().map(|s| s.to_string()));
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.optim.threads, 8);
        let args = Args::parse(["--threads", "0"].iter().map(|s| s.to_string()));
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.optim.threads, 1);
    }

    #[test]
    fn resume_and_save_every_plumb_through() {
        let doc = TomlDoc::parse(
            "[train]\nresume = \"runs/a/checkpoint.bin\"\nsave_every = 50",
        )
        .unwrap();
        let mut cfg = ExperimentConfig::default();
        assert!(cfg.resume.is_none());
        assert_eq!(cfg.save_every, 0);
        cfg.apply_toml(&doc).unwrap();
        assert_eq!(cfg.resume.as_deref(), Some("runs/a/checkpoint.bin"));
        assert_eq!(cfg.save_every, 50);
        // CLI overrides the TOML values.
        let args = Args::parse(
            ["--resume", "other.bin", "--save-every", "10"].iter().map(|s| s.to_string()),
        );
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.resume.as_deref(), Some("other.bin"));
        assert_eq!(cfg.save_every, 10);
        // absent flags leave the config untouched
        cfg.apply_args(&Args::parse(std::iter::empty::<String>())).unwrap();
        assert_eq!(cfg.resume.as_deref(), Some("other.bin"));
        assert_eq!(cfg.save_every, 10);
        // Top-level spelling (next to steps/log_every) works too.
        let doc = TomlDoc::parse("steps = 7\nresume = \"top.bin\"\nsave_every = 3").unwrap();
        let mut cfg2 = ExperimentConfig::default();
        cfg2.apply_toml(&doc).unwrap();
        assert_eq!(cfg2.resume.as_deref(), Some("top.bin"));
        assert_eq!(cfg2.save_every, 3);
        assert_eq!(cfg2.steps, 7);
        // ...and grouping the sibling knobs under [train] is honored,
        // not silently ignored.
        let doc = TomlDoc::parse(
            "[train]\nsteps = 500\nlog_every = 25\nout_dir = \"runs2\"\nsave_every = 50",
        )
        .unwrap();
        let mut cfg3 = ExperimentConfig::default();
        cfg3.apply_toml(&doc).unwrap();
        assert_eq!(cfg3.steps, 500);
        assert_eq!(cfg3.log_every, 25);
        assert_eq!(cfg3.out_dir, "runs2");
        assert_eq!(cfg3.save_every, 50);
    }

    #[test]
    fn groups_plumb_through_toml_and_cli() {
        let doc = TomlDoc::parse(
            "[optimizer]\nkind = \"smmf\"\nweight_decay = 0.01\n\
             [[optimizer.group]]\nname = \"no_decay\"\nmatch_role = [\"bias\", \"norm\"]\nweight_decay = 0.0\n\
             [[optimizer.group]]\nname = \"emb\"\nmatch_name = \"*emb*\"\nlr_scale = 0.5\nstate = \"dense\"\n",
        )
        .unwrap();
        let mut cfg = ExperimentConfig::default();
        cfg.apply_toml(&doc).unwrap();
        assert_eq!(cfg.groups.len(), 2);
        assert_eq!(cfg.groups[0].name, "no_decay");
        assert_eq!(cfg.groups[0].match_roles, vec![ParamRole::Bias, ParamRole::Norm]);
        assert_eq!(cfg.groups[0].weight_decay, Some(0.0));
        assert_eq!(cfg.groups[1].match_names, vec!["*emb*".to_string()]);
        assert_eq!(cfg.groups[1].state, StatePolicy::Dense);
        assert!((cfg.groups[1].lr_scale - 0.5).abs() < 1e-9);
        // switching the optimizer keeps the groups (recipe-independent)
        let args = Args::parse(["--optimizer", "adam"].iter().map(|s| s.to_string()));
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.groups.len(), 2);
        // --group replaces the TOML groups
        let args = Args::parse(
            ["--group", "name=cli,role=bias,wd=0;match=head.*,frozen"]
                .iter()
                .map(|s| s.to_string()),
        );
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.groups.len(), 2);
        assert_eq!(cfg.groups[0].name, "cli");
        assert!(cfg.groups[1].frozen);
        // grouped() carries base + groups
        let g = cfg.grouped();
        assert_eq!(g.groups.len(), 2);
        // bad specs error
        let args = Args::parse(["--group", "role=nope"].iter().map(|s| s.to_string()));
        assert!(cfg.apply_args(&args).is_err());
        // bad TOML role errors
        let doc = TomlDoc::parse("[[optimizer.group]]\nmatch_role = \"bogus\"\n").unwrap();
        assert!(ExperimentConfig::default().apply_toml(&doc).is_err());
    }

    #[test]
    fn bias_correction_knob_plumbs_through() {
        // paper defaults: off for Adam/AdamW (pre-training configs)
        let mut cfg = ExperimentConfig::default();
        cfg.apply_args(&Args::parse(["--optimizer", "adam"].iter().map(|s| s.to_string())))
            .unwrap();
        assert!(!cfg.optim.bias_correction);
        // TOML opts back in
        let doc = TomlDoc::parse("[optimizer]\nkind = \"adam\"\nbias_correction = true").unwrap();
        let mut cfg2 = ExperimentConfig::default();
        cfg2.apply_toml(&doc).unwrap();
        assert!(cfg2.optim.bias_correction);
        // CLI wins over TOML
        cfg2.apply_args(&Args::parse(
            ["--bias-correction", "false"].iter().map(|s| s.to_string()),
        ))
        .unwrap();
        assert!(!cfg2.optim.bias_correction);
        assert!(cfg2
            .apply_args(&Args::parse(["--bias-correction", "maybe"].iter().map(|s| s.to_string())))
            .is_err());
    }

    #[test]
    fn suite_workers_validated_not_clamped() {
        let base = "[[suite.run]]\noptimizers = [\"smmf\"]\nmodels = [\"synthetic:tiny_lm\"]\n";
        let ok = SuiteConfig::parse(&format!("[suite]\nworkers = 3\n{base}"), "s").unwrap();
        assert_eq!(ok.workers, WorkerSpec::local(3));
        // absent -> default 1
        assert_eq!(SuiteConfig::parse(base, "s").unwrap().workers, WorkerSpec::local(1));
        // zero/negative pools error with the count_or message, never clamp
        for bad in ["workers = 0", "workers = -2"] {
            let e = SuiteConfig::parse(&format!("[suite]\n{bad}\n{base}"), "s").unwrap_err();
            assert!(format!("{e:#}").contains(">= 1"), "{bad}: {e:#}");
        }
        // a string that is neither a count nor a backend spec errors too
        let e = SuiteConfig::parse(&format!("[suite]\nworkers = \"many\"\n{base}"), "s")
            .unwrap_err();
        assert!(format!("{e:#}").contains("bad workers entry"), "{e:#}");
        // string spellings route through WorkerSpec
        let ok = SuiteConfig::parse(
            &format!("[suite]\nworkers = \"local:2,remote:127.0.0.1:7131\"\n{base}"),
            "s",
        )
        .unwrap();
        assert_eq!(
            ok.workers,
            WorkerSpec { local: 2, remote: vec!["127.0.0.1:7131".into()] }
        );
    }

    #[test]
    fn worker_spec_parsing() {
        // integers and local:N are the thread pool
        assert_eq!(WorkerSpec::parse("4"), Ok(WorkerSpec::local(4)));
        assert_eq!(WorkerSpec::parse(" local:2 "), Ok(WorkerSpec::local(2)));
        // remote lists: explicit prefix per entry or bare continuations
        let two = WorkerSpec { local: 0, remote: vec!["a:1".into(), "b:2".into()] };
        assert_eq!(WorkerSpec::parse("remote:a:1,remote:b:2"), Ok(two.clone()));
        assert_eq!(WorkerSpec::parse("remote:a:1,b:2"), Ok(two));
        // mixed, in either order
        let mixed = WorkerSpec { local: 1, remote: vec!["h:9".into()] };
        assert_eq!(WorkerSpec::parse("local:1,remote:h:9"), Ok(mixed.clone()));
        assert_eq!(WorkerSpec::parse("remote:h:9,local:1"), Ok(mixed.clone()));
        assert!(!mixed.is_local_only());
        assert!(WorkerSpec::local(3).is_local_only());
        // errors: bad counts, port-less addresses, duplicates, emptiness
        for bad in [
            "0",
            "-2",
            "local:0",
            "local:x",
            "many",
            "remote:nocolon",
            "remote:a:1,a:1",
            "remote:a:1,local:1,local:2",
            "",
        ] {
            assert!(WorkerSpec::parse(bad).is_err(), "{bad:?} should not parse");
        }
    }

    /// The `SMMFCELL` wire contract: every cell config a suite can
    /// expand — groups, schedules, per-block overrides included — must
    /// survive `to_toml` -> `from_toml_str` exactly (the remote worker
    /// rebuilds the config from this text alone).
    #[test]
    fn experiment_config_round_trips_through_toml_text() {
        let text = r#"
[suite]
name = "rt"
seeds = [0, 3]

[optimizer]
kind = "smmf"
lr = 0.0123
weight_decay = 0.01
decay_rate = -0.7
threads = 2
weight_decay_mode = "adam"

[[optimizer.group]]
name = "no_decay"
match_role = ["bias", "norm"]
weight_decay = 0.0
state = "dense"

[[optimizer.group]]
name = "emb"
match_name = ["*emb*", "tok?"]
lr_scale = 0.5
frozen = true

[schedule]
kind = "linear"
warmup = 7
total = 40

[train]
steps = 40
log_every = 5

[[suite.run]]
optimizers = ["adam", "came", "adafactor"]
models = ["synthetic:tiny_lm"]

[[suite.run]]
label = "hot"
optimizers = ["smmf", "sm3", "sgd"]
models = ["synthetic:tiny_lm"]
lr = 0.05
steps = 9
save_every = 4
"#;
        let suite = SuiteConfig::parse(text, "rt").unwrap();
        let cells = suite.expand().unwrap();
        assert!(cells.len() >= 12);
        for cell in &cells {
            let rendered = cell.cfg.to_toml().unwrap();
            let back = ExperimentConfig::from_toml_str(&rendered).unwrap();
            assert_eq!(back, cell.cfg, "cell {} drifted through the wire TOML", cell.run);
            // canonical form is a fixpoint
            assert_eq!(back.to_toml().unwrap(), rendered);
        }
        // non-finite floats and unrepresentable schedules are rejected,
        // not silently mangled
        let mut bad = cells[0].cfg.clone();
        bad.optim.lr = f32::NAN;
        assert!(bad.to_toml().is_err());
        let mut cos = cells[0].cfg.clone();
        cos.schedule = LrSchedule::Cosine { warmup: 1, total: 2, floor: 0.1 };
        assert!(cos.to_toml().is_err());
    }

    #[test]
    fn bad_optimizer_errors() {
        let mut cfg = ExperimentConfig::default();
        let args = Args::parse(["--optimizer", "sgdx"].iter().map(|s| s.to_string()));
        assert!(cfg.apply_args(&args).is_err());
    }
}
