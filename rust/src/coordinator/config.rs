//! Experiment configuration: TOML file + CLI overrides.

use anyhow::{anyhow, Result};
use std::path::Path;

use crate::optim::group::{GroupPolicy, GroupedConfig, ParamRole, StatePolicy};
use crate::optim::{OptKind, OptimConfig, WeightDecayMode};
use crate::optim::schedule::LrSchedule;
use crate::util::cli::Args;
use crate::util::toml::TomlDoc;

/// Everything a training experiment needs.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub name: String,
    pub artifact: String,
    pub optimizer: OptKind,
    pub optim: OptimConfig,
    /// Param-group matcher blocks (`[[optimizer.group]]` / `--group`),
    /// resolved against the inventory at build time (first match wins).
    pub groups: Vec<GroupPolicy>,
    pub steps: u64,
    pub seed: u64,
    pub log_every: u64,
    pub out_dir: String,
    pub schedule: LrSchedule,
    pub workers: usize,
    /// Resume from this `SMMFCKPT` checkpoint before training
    /// (`--resume <path>` / `[train] resume = "..."`).
    pub resume: Option<String>,
    /// Write `runs/<name>/checkpoint.bin` every N steps and at the end
    /// (0 = checkpointing off; `--save-every N` / `[train] save_every`).
    pub save_every: u64,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        Self {
            name: "run".into(),
            artifact: "lm_tiny_grads".into(),
            optimizer: OptKind::Smmf,
            optim: OptimConfig::paper_defaults(OptKind::Smmf),
            groups: Vec::new(),
            steps: 200,
            seed: 0,
            log_every: 10,
            out_dir: "runs".into(),
            schedule: LrSchedule::Constant,
            workers: 1,
            resume: None,
            save_every: 0,
        }
    }
}

impl ExperimentConfig {
    /// Load from a TOML file (all keys optional).
    pub fn from_toml(path: &Path) -> Result<ExperimentConfig> {
        let text = std::fs::read_to_string(path)?;
        let doc = TomlDoc::parse(&text).map_err(|e| anyhow!("{path:?}: {e}"))?;
        let mut cfg = ExperimentConfig::default();
        cfg.apply_toml(&doc)?;
        Ok(cfg)
    }

    pub fn apply_toml(&mut self, doc: &TomlDoc) -> Result<()> {
        self.name = doc.str_or("name", &self.name).to_string();
        self.artifact = doc.str_or("artifact", &self.artifact).to_string();
        if let Some(k) = doc.get("optimizer.kind").and_then(|v| v.as_str()) {
            self.set_optimizer(k)?;
        }
        // Train-loop knobs are accepted both at the top level (the
        // historical spelling) and grouped under `[train]` — whichever
        // grouping the user picks, no key is silently ignored. The
        // `[train]` spelling wins when both are present.
        let i64_either = |key: &str, current: i64| -> i64 {
            doc.i64_or(&format!("train.{key}"), doc.i64_or(key, current))
        };
        self.steps = i64_either("steps", self.steps as i64) as u64;
        self.seed = i64_either("seed", self.seed as i64) as u64;
        self.log_every = i64_either("log_every", self.log_every as i64) as u64;
        self.workers = i64_either("workers", self.workers as i64) as usize;
        self.save_every = i64_either("save_every", self.save_every as i64).max(0) as u64;
        self.out_dir = doc
            .get("train.out_dir")
            .and_then(|v| v.as_str())
            .unwrap_or_else(|| doc.str_or("out_dir", &self.out_dir))
            .to_string();
        if let Some(path) =
            doc.get("train.resume").or_else(|| doc.get("resume")).and_then(|v| v.as_str())
        {
            self.resume = Some(path.to_string());
        }
        // `[[optimizer.group]]` matcher blocks (name-glob / role
        // selectors + per-group overrides). When present they replace the
        // current group list, so a TOML file fully specifies its groups.
        let n_groups = doc.array_len("optimizer.group");
        if n_groups > 0 {
            let mut groups = Vec::with_capacity(n_groups);
            for i in 0..n_groups {
                let pre = format!("optimizer.group.{i}");
                let mut g = GroupPolicy {
                    name: doc.str_or(&format!("{pre}.name"), &format!("group{i}")).to_string(),
                    ..GroupPolicy::default()
                };
                if let Some(roles) = doc.str_list(&format!("{pre}.match_role")) {
                    for r in roles {
                        let role = ParamRole::parse(&r)
                            .ok_or_else(|| anyhow!("group {i}: unknown role {r}"))?;
                        g.match_roles.push(role);
                    }
                }
                if let Some(names) = doc.str_list(&format!("{pre}.match_name")) {
                    g.match_names = names;
                }
                g.lr_scale = doc.f64_or(&format!("{pre}.lr_scale"), g.lr_scale as f64) as f32;
                if let Some(wd) = doc.get(&format!("{pre}.weight_decay")).and_then(|v| v.as_f64())
                {
                    g.weight_decay = Some(wd as f32);
                }
                g.frozen = doc.bool_or(&format!("{pre}.frozen"), g.frozen);
                if let Some(s) = doc.get(&format!("{pre}.state")).and_then(|v| v.as_str()) {
                    g.state = StatePolicy::parse(s)
                        .ok_or_else(|| anyhow!("group {}: unknown state policy {s}", g.name))?;
                }
                groups.push(g);
            }
            self.groups = groups;
        }
        let o = &mut self.optim;
        o.lr = doc.f64_or("optimizer.lr", o.lr as f64) as f32;
        o.beta1 = doc.f64_or("optimizer.beta1", o.beta1 as f64) as f32;
        o.beta2 = doc.f64_or("optimizer.beta2", o.beta2 as f64) as f32;
        o.weight_decay = doc.f64_or("optimizer.weight_decay", o.weight_decay as f64) as f32;
        o.decay_rate = doc.f64_or("optimizer.decay_rate", o.decay_rate as f64) as f32;
        o.growth_rate = doc.f64_or("optimizer.growth_rate", o.growth_rate as f64) as f32;
        o.vector_reshape = doc.bool_or("optimizer.vector_reshape", o.vector_reshape);
        // Paper defaults disable Adam/AdamW bias correction (pre-training
        // configs); this key opts back in per run.
        o.bias_correction = doc.bool_or("optimizer.bias_correction", o.bias_correction);
        // Parallel step engine worker threads (>= 1; 1 = serial).
        o.threads = (doc.i64_or("optimizer.threads", o.threads as i64).max(1)) as usize;
        if let Some(mode) = doc.get("optimizer.weight_decay_mode").and_then(|v| v.as_str()) {
            o.weight_decay_mode = match mode {
                "adam" => WeightDecayMode::Adam,
                "adamw" => WeightDecayMode::AdamW,
                other => return Err(anyhow!("bad weight_decay_mode {other}")),
            };
        }
        match doc.str_or("schedule.kind", "constant") {
            "constant" => self.schedule = LrSchedule::Constant,
            "warmup" => {
                self.schedule =
                    LrSchedule::Warmup { warmup: doc.i64_or("schedule.warmup", 100) as u64 }
            }
            "linear" => {
                self.schedule = LrSchedule::Linear {
                    warmup: doc.i64_or("schedule.warmup", 100) as u64,
                    total: doc.i64_or("schedule.total", self.steps as i64) as u64,
                }
            }
            "invsqrt" => {
                self.schedule =
                    LrSchedule::InvSqrt { warmup: doc.i64_or("schedule.warmup", 100) as u64 }
            }
            other => return Err(anyhow!("bad schedule.kind {other}")),
        }
        Ok(())
    }

    /// Apply `--key value` CLI overrides on top.
    pub fn apply_args(&mut self, args: &Args) -> Result<()> {
        if let Some(k) = args.opt("optimizer") {
            self.set_optimizer(k)?;
        }
        if let Some(a) = args.opt("artifact") {
            self.artifact = a.to_string();
        }
        if let Some(n) = args.opt("name") {
            self.name = n.to_string();
        }
        self.steps = args.u64_or("steps", self.steps);
        self.seed = args.u64_or("seed", self.seed);
        self.log_every = args.u64_or("log-every", self.log_every);
        self.workers = args.positive_usize_or("workers", self.workers);
        self.out_dir = args.str_or("out-dir", &self.out_dir);
        if let Some(path) = args.opt("resume") {
            self.resume = Some(path.to_string());
        }
        self.save_every = args.u64_or("save-every", self.save_every);
        // `--group "name=no_decay,role=bias|norm,wd=0; match=*emb*,lr_scale=0.5"`
        // replaces any TOML-defined groups (CLI wins, like every other knob).
        if let Some(specs) = args.opt("group") {
            self.groups = GroupPolicy::parse_cli_list(specs).map_err(|e| anyhow!("--group: {e}"))?;
        }
        self.optim.threads = args.positive_usize_or("threads", self.optim.threads);
        self.optim.lr = args.f64_or("lr", self.optim.lr as f64) as f32;
        self.optim.weight_decay = args.f64_or("weight-decay", self.optim.weight_decay as f64) as f32;
        self.optim.decay_rate = args.f64_or("decay-rate", self.optim.decay_rate as f64) as f32;
        if let Some(v) = args.opt("bias-correction") {
            self.optim.bias_correction = match v {
                "true" | "1" | "on" => true,
                "false" | "0" | "off" => false,
                other => return Err(anyhow!("bad --bias-correction {other} (true/false)")),
            };
        }
        Ok(())
    }

    /// The grouped optimizer config this experiment resolves to.
    pub fn grouped(&self) -> GroupedConfig {
        GroupedConfig { base: self.optim.clone(), groups: self.groups.clone() }
    }

    fn set_optimizer(&mut self, kind: &str) -> Result<()> {
        let k = OptKind::parse(kind).ok_or_else(|| anyhow!("unknown optimizer {kind}"))?;
        // Re-derive paper defaults for the new kind, preserving the
        // recipe-independent knobs (lr, engine threads).
        let lr = self.optim.lr;
        let threads = self.optim.threads;
        self.optimizer = k;
        self.optim = OptimConfig::paper_defaults(k);
        self.optim.lr = lr;
        self.optim.threads = threads;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn toml_then_cli_overrides() {
        let doc = TomlDoc::parse(
            "name = \"fig2\"\nsteps = 400\n[optimizer]\nkind = \"came\"\nlr = 0.002\n[schedule]\nkind = \"warmup\"\nwarmup = 50",
        )
        .unwrap();
        let mut cfg = ExperimentConfig::default();
        cfg.apply_toml(&doc).unwrap();
        assert_eq!(cfg.optimizer, OptKind::Came);
        assert_eq!(cfg.steps, 400);
        assert!((cfg.optim.lr - 0.002).abs() < 1e-9);
        assert_eq!(cfg.schedule, LrSchedule::Warmup { warmup: 50 });
        // CAME paper defaults picked up
        assert!((cfg.optim.eps2 - 1e-16).abs() < 1e-20);

        let args = Args::parse(
            ["--optimizer", "smmf", "--steps", "10", "--lr", "0.01"].iter().map(|s| s.to_string()),
        );
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.optimizer, OptKind::Smmf);
        assert_eq!(cfg.steps, 10);
        assert!((cfg.optim.lr - 0.01).abs() < 1e-9);
    }

    #[test]
    fn threads_plumb_through_toml_and_cli() {
        let doc = TomlDoc::parse("[optimizer]\nkind = \"smmf\"\nthreads = 4").unwrap();
        let mut cfg = ExperimentConfig::default();
        cfg.apply_toml(&doc).unwrap();
        assert_eq!(cfg.optim.threads, 4);
        // Switching the optimizer on the CLI must not reset threads...
        let args = Args::parse(["--optimizer", "adam"].iter().map(|s| s.to_string()));
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.optimizer, OptKind::Adam);
        assert_eq!(cfg.optim.threads, 4);
        // ...and --threads overrides (clamped to >= 1).
        let args = Args::parse(["--threads", "8"].iter().map(|s| s.to_string()));
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.optim.threads, 8);
        let args = Args::parse(["--threads", "0"].iter().map(|s| s.to_string()));
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.optim.threads, 1);
    }

    #[test]
    fn resume_and_save_every_plumb_through() {
        let doc = TomlDoc::parse(
            "[train]\nresume = \"runs/a/checkpoint.bin\"\nsave_every = 50",
        )
        .unwrap();
        let mut cfg = ExperimentConfig::default();
        assert!(cfg.resume.is_none());
        assert_eq!(cfg.save_every, 0);
        cfg.apply_toml(&doc).unwrap();
        assert_eq!(cfg.resume.as_deref(), Some("runs/a/checkpoint.bin"));
        assert_eq!(cfg.save_every, 50);
        // CLI overrides the TOML values.
        let args = Args::parse(
            ["--resume", "other.bin", "--save-every", "10"].iter().map(|s| s.to_string()),
        );
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.resume.as_deref(), Some("other.bin"));
        assert_eq!(cfg.save_every, 10);
        // absent flags leave the config untouched
        cfg.apply_args(&Args::parse(std::iter::empty::<String>())).unwrap();
        assert_eq!(cfg.resume.as_deref(), Some("other.bin"));
        assert_eq!(cfg.save_every, 10);
        // Top-level spelling (next to steps/log_every) works too.
        let doc = TomlDoc::parse("steps = 7\nresume = \"top.bin\"\nsave_every = 3").unwrap();
        let mut cfg2 = ExperimentConfig::default();
        cfg2.apply_toml(&doc).unwrap();
        assert_eq!(cfg2.resume.as_deref(), Some("top.bin"));
        assert_eq!(cfg2.save_every, 3);
        assert_eq!(cfg2.steps, 7);
        // ...and grouping the sibling knobs under [train] is honored,
        // not silently ignored.
        let doc = TomlDoc::parse(
            "[train]\nsteps = 500\nlog_every = 25\nout_dir = \"runs2\"\nsave_every = 50",
        )
        .unwrap();
        let mut cfg3 = ExperimentConfig::default();
        cfg3.apply_toml(&doc).unwrap();
        assert_eq!(cfg3.steps, 500);
        assert_eq!(cfg3.log_every, 25);
        assert_eq!(cfg3.out_dir, "runs2");
        assert_eq!(cfg3.save_every, 50);
    }

    #[test]
    fn groups_plumb_through_toml_and_cli() {
        let doc = TomlDoc::parse(
            "[optimizer]\nkind = \"smmf\"\nweight_decay = 0.01\n\
             [[optimizer.group]]\nname = \"no_decay\"\nmatch_role = [\"bias\", \"norm\"]\nweight_decay = 0.0\n\
             [[optimizer.group]]\nname = \"emb\"\nmatch_name = \"*emb*\"\nlr_scale = 0.5\nstate = \"dense\"\n",
        )
        .unwrap();
        let mut cfg = ExperimentConfig::default();
        cfg.apply_toml(&doc).unwrap();
        assert_eq!(cfg.groups.len(), 2);
        assert_eq!(cfg.groups[0].name, "no_decay");
        assert_eq!(cfg.groups[0].match_roles, vec![ParamRole::Bias, ParamRole::Norm]);
        assert_eq!(cfg.groups[0].weight_decay, Some(0.0));
        assert_eq!(cfg.groups[1].match_names, vec!["*emb*".to_string()]);
        assert_eq!(cfg.groups[1].state, StatePolicy::Dense);
        assert!((cfg.groups[1].lr_scale - 0.5).abs() < 1e-9);
        // switching the optimizer keeps the groups (recipe-independent)
        let args = Args::parse(["--optimizer", "adam"].iter().map(|s| s.to_string()));
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.groups.len(), 2);
        // --group replaces the TOML groups
        let args = Args::parse(
            ["--group", "name=cli,role=bias,wd=0;match=head.*,frozen"]
                .iter()
                .map(|s| s.to_string()),
        );
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.groups.len(), 2);
        assert_eq!(cfg.groups[0].name, "cli");
        assert!(cfg.groups[1].frozen);
        // grouped() carries base + groups
        let g = cfg.grouped();
        assert_eq!(g.groups.len(), 2);
        // bad specs error
        let args = Args::parse(["--group", "role=nope"].iter().map(|s| s.to_string()));
        assert!(cfg.apply_args(&args).is_err());
        // bad TOML role errors
        let doc = TomlDoc::parse("[[optimizer.group]]\nmatch_role = \"bogus\"\n").unwrap();
        assert!(ExperimentConfig::default().apply_toml(&doc).is_err());
    }

    #[test]
    fn bias_correction_knob_plumbs_through() {
        // paper defaults: off for Adam/AdamW (pre-training configs)
        let mut cfg = ExperimentConfig::default();
        cfg.apply_args(&Args::parse(["--optimizer", "adam"].iter().map(|s| s.to_string())))
            .unwrap();
        assert!(!cfg.optim.bias_correction);
        // TOML opts back in
        let doc = TomlDoc::parse("[optimizer]\nkind = \"adam\"\nbias_correction = true").unwrap();
        let mut cfg2 = ExperimentConfig::default();
        cfg2.apply_toml(&doc).unwrap();
        assert!(cfg2.optim.bias_correction);
        // CLI wins over TOML
        cfg2.apply_args(&Args::parse(
            ["--bias-correction", "false"].iter().map(|s| s.to_string()),
        ))
        .unwrap();
        assert!(!cfg2.optim.bias_correction);
        assert!(cfg2
            .apply_args(&Args::parse(["--bias-correction", "maybe"].iter().map(|s| s.to_string())))
            .is_err());
    }

    #[test]
    fn bad_optimizer_errors() {
        let mut cfg = ExperimentConfig::default();
        let args = Args::parse(["--optimizer", "sgdx"].iter().map(|s| s.to_string()));
        assert!(cfg.apply_args(&args).is_err());
    }
}
