//! Multi-worker pools (std::thread): the data-parallel trainer and the
//! generic task fan-out the experiment-suite scheduler reuses.
//!
//! Two topologies share this module:
//!
//! * [`train_data_parallel`] — lockstep leader/worker data parallelism:
//!   each worker owns its own PJRT client and compiled executable,
//!   receives the current parameters, computes gradients on its private
//!   shard of the batch stream, and sends them back; the leader averages
//!   gradients and applies one optimizer step. This exercises the
//!   framework's distributed shape on a single host; on this testbed
//!   (1 core) it is a correctness/topology feature, not a speedup.
//! * [`fan_out`] — an order-preserving work-stealing pool for
//!   *independent* tasks (no per-step barrier). `repro suite` schedules
//!   its expanded run matrix over it; each suite cell opens its own
//!   runtime inside the worker, exactly like the data-parallel workers
//!   do.

use anyhow::{anyhow, Result};
use std::collections::VecDeque;
use std::sync::{mpsc, Mutex};
use std::thread;

use crate::coordinator::config::ExperimentConfig;
use crate::coordinator::experiments::BatchSource;
use crate::optim;
use crate::optim::group::{self, ParamSpec};
use crate::runtime::Runtime;
use crate::tensor::Tensor;
use crate::train::TrainGraph;

/// Run `tasks` over a pool of `n_workers` scoped threads and return the
/// results in task order. Workers pull from a shared queue, so uneven
/// task costs balance automatically; `f` receives `(task index, task)`.
/// Failure isolation is the *caller's* job: have `f` return a
/// `Result`-like value rather than panic (a panicking task tears down
/// the whole pool, like any thread panic) — or use
/// [`fan_out_recover`], which maps a per-task panic into a caller-chosen
/// failure value instead.
pub fn fan_out<T, R>(
    tasks: Vec<T>,
    n_workers: usize,
    f: impl Fn(usize, T) -> R + Sync,
) -> Vec<R>
where
    T: Send,
    R: Send,
{
    fan_out_impl(tasks, n_workers, &f)
}

/// [`fan_out`] with panic isolation: a task that panics no longer
/// poisons the whole pool — the panic is caught on the worker thread,
/// `recover(index, panic message)` produces that slot's result, and the
/// worker moves on to the next task. `repro suite` uses this to turn a
/// panicking cell into a `FAILED` marker instead of an aborted sweep.
pub fn fan_out_recover<T, R>(
    tasks: Vec<T>,
    n_workers: usize,
    f: impl Fn(usize, T) -> R + Sync,
    recover: impl Fn(usize, String) -> R + Sync,
) -> Vec<R>
where
    T: Send,
    R: Send,
{
    fan_out_impl(tasks, n_workers, &|i, t| {
        match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(i, t))) {
            Ok(r) => r,
            Err(payload) => recover(i, panic_note(payload.as_ref())),
        }
    })
}

/// Render a caught panic payload as a short human-readable note
/// (panics carry `&str` or `String` in practice).
pub(crate) fn panic_note(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "panicked with a non-string payload".to_string()
    }
}

fn fan_out_impl<T, R>(
    tasks: Vec<T>,
    n_workers: usize,
    f: &(impl Fn(usize, T) -> R + Sync),
) -> Vec<R>
where
    T: Send,
    R: Send,
{
    let n = tasks.len();
    let n_workers = n_workers.max(1).min(n.max(1));
    let queue: Mutex<VecDeque<(usize, T)>> = Mutex::new(tasks.into_iter().enumerate().collect());
    let (tx, rx) = mpsc::channel::<(usize, R)>();
    thread::scope(|s| {
        for _ in 0..n_workers {
            let tx = tx.clone();
            let queue = &queue;
            let f = &f;
            s.spawn(move || loop {
                let item = queue.lock().unwrap().pop_front();
                match item {
                    Some((i, t)) => {
                        // A send can only fail if the leader is gone —
                        // nothing useful left to do with the result then.
                        tx.send((i, f(i, t))).ok();
                    }
                    None => break,
                }
            });
        }
        drop(tx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for (i, r) in rx {
            out[i] = Some(r);
        }
        out.into_iter()
            .map(|r| r.expect("fan_out worker delivered every task"))
            .collect()
    })
}

enum ToWorker {
    Params(Vec<Tensor>),
    Stop,
}

struct FromWorker {
    worker: usize,
    loss: f32,
    grads: Vec<Tensor>,
}

/// Run synchronous data-parallel training; returns per-step mean losses.
pub fn train_data_parallel(
    artifact_dir: &str,
    cfg: &ExperimentConfig,
    n_workers: usize,
) -> Result<Vec<f32>> {
    assert!(n_workers >= 1);
    let rt = Runtime::open(artifact_dir)?;
    let graph = TrainGraph::load(&rt, &cfg.artifact)?;
    let shapes = graph.param_shapes();
    // Same grouped construction as `run_experiment`: param-group
    // overrides apply to the leader's optimizer step here too.
    let specs: Vec<ParamSpec> = graph
        .spec()
        .params
        .iter()
        .map(|p| ParamSpec::inferred(p.name.clone(), &p.shape))
        .collect();
    let res = group::resolve(&specs, &cfg.grouped());
    let mut opt = optim::build_with_policies(cfg.optimizer, &shapes, &cfg.optim, &res.tensor);
    let mut params = graph.init_params(cfg.seed);
    drop(graph);
    drop(rt);

    let (result_tx, result_rx) = mpsc::channel::<Result<FromWorker>>();
    let mut cmd_txs = Vec::new();
    let mut handles = Vec::new();
    for w in 0..n_workers {
        let (cmd_tx, cmd_rx) = mpsc::channel::<ToWorker>();
        cmd_txs.push(cmd_tx);
        let result_tx = result_tx.clone();
        let artifact_dir = artifact_dir.to_string();
        let artifact = cfg.artifact.clone();
        let seed = cfg.seed;
        handles.push(thread::spawn(move || {
            let run = || -> Result<()> {
                let rt = Runtime::open(&artifact_dir)?;
                let graph = TrainGraph::load(&rt, &artifact)?;
                // Each worker streams a disjoint shard (distinct seed).
                let mut source = BatchSource::for_spec(graph.spec(), seed ^ (w as u64) << 17)?;
                let mut grads = Vec::new();
                loop {
                    match cmd_rx.recv() {
                        Ok(ToWorker::Params(params)) => {
                            let batch = source.next()?;
                            let loss = graph.loss_and_grads(&params, &batch, &mut grads)?;
                            result_tx
                                .send(Ok(FromWorker {
                                    worker: w,
                                    loss,
                                    grads: std::mem::take(&mut grads),
                                }))
                                .ok();
                        }
                        Ok(ToWorker::Stop) | Err(_) => return Ok(()),
                    }
                }
            };
            if let Err(e) = run() {
                result_tx.send(Err(anyhow!("worker {w}: {e}"))).ok();
            }
        }));
    }

    let mut losses = Vec::with_capacity(cfg.steps as usize);
    let mut avg: Vec<Tensor> = shapes.iter().map(|s| Tensor::zeros(s)).collect();
    for _step in 0..cfg.steps {
        for tx in &cmd_txs {
            tx.send(ToWorker::Params(params.clone())).map_err(|_| anyhow!("worker died"))?;
        }
        avg.iter_mut().for_each(|t| t.fill(0.0));
        let mut loss_sum = 0.0f32;
        for _ in 0..n_workers {
            let msg = result_rx.recv().map_err(|_| anyhow!("workers gone"))??;
            loss_sum += msg.loss;
            for (a, g) in avg.iter_mut().zip(&msg.grads) {
                a.axpy(1.0 / n_workers as f32, g);
            }
            let _ = msg.worker;
        }
        opt.step(&mut params, &avg);
        losses.push(loss_sum / n_workers as f32);
    }
    for tx in &cmd_txs {
        tx.send(ToWorker::Stop).ok();
    }
    for h in handles {
        h.join().map_err(|_| anyhow!("worker panicked"))?;
    }
    Ok(losses)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fan_out_preserves_order_and_handles_edges() {
        let tasks: Vec<usize> = (0..50).collect();
        let out = fan_out(tasks, 4, |i, t| {
            assert_eq!(i, t);
            t * 2
        });
        assert_eq!(out, (0..50).map(|t| t * 2).collect::<Vec<_>>());
        // empty task list, and more workers than tasks
        let empty: Vec<usize> = Vec::new();
        assert!(fan_out(empty, 3, |_, t: usize| t).is_empty());
        assert_eq!(fan_out(vec![7usize], 8, |_, t| t + 1), vec![8]);
        // error values pass through per-task (failure isolation pattern)
        let out = fan_out(vec![1usize, 0, 3], 2, |_, t| {
            if t == 0 {
                Err("zero")
            } else {
                Ok(t)
            }
        });
        assert_eq!(out, vec![Ok(1), Err("zero"), Ok(3)]);
    }

    /// A panicking task must surface as that slot's recovered value —
    /// not poison the pool: every other task still completes, order is
    /// preserved, and the panic message reaches the recovery hook.
    #[test]
    fn fan_out_recover_isolates_panicking_tasks() {
        let tasks: Vec<usize> = (0..24).collect();
        let out = fan_out_recover(
            tasks,
            3,
            |_, t| if t % 7 == 3 { panic!("boom {t}") } else { Ok(t) },
            |i, note| Err(format!("task {i}: {note}")),
        );
        assert_eq!(out.len(), 24);
        for (i, r) in out.iter().enumerate() {
            if i % 7 == 3 {
                assert_eq!(r, &Err(format!("task {i}: boom {i}")));
            } else {
                assert_eq!(r, &Ok(i));
            }
        }
        // String panic payloads (format!-style) are captured too.
        let out = fan_out_recover(
            vec![0usize],
            1,
            |_, _| -> &'static str { std::panic::panic_any("typed".to_string()) },
            |_, note| if note == "typed" { "recovered" } else { "wrong note" },
        );
        assert_eq!(out, vec!["recovered"]);
    }
}
