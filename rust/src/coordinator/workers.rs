//! Multi-worker data-parallel training (std::thread).
//!
//! Leader/worker topology: each worker owns its own PJRT client and
//! compiled executable, receives the current parameters, computes
//! gradients on its private shard of the batch stream, and sends them
//! back; the leader averages gradients and applies one optimizer step
//! (synchronous data parallelism). This exercises the framework's
//! distributed shape on a single host; on this testbed (1 core) it is a
//! correctness/topology feature, not a speedup.

use anyhow::{anyhow, Result};
use std::sync::mpsc;
use std::thread;

use crate::coordinator::config::ExperimentConfig;
use crate::coordinator::experiments::BatchSource;
use crate::optim;
use crate::optim::group::{self, ParamSpec};
use crate::runtime::Runtime;
use crate::tensor::Tensor;
use crate::train::TrainGraph;

enum ToWorker {
    Params(Vec<Tensor>),
    Stop,
}

struct FromWorker {
    worker: usize,
    loss: f32,
    grads: Vec<Tensor>,
}

/// Run synchronous data-parallel training; returns per-step mean losses.
pub fn train_data_parallel(
    artifact_dir: &str,
    cfg: &ExperimentConfig,
    n_workers: usize,
) -> Result<Vec<f32>> {
    assert!(n_workers >= 1);
    let rt = Runtime::open(artifact_dir)?;
    let graph = TrainGraph::load(&rt, &cfg.artifact)?;
    let shapes = graph.param_shapes();
    // Same grouped construction as `run_experiment`: param-group
    // overrides apply to the leader's optimizer step here too.
    let specs: Vec<ParamSpec> = graph
        .spec()
        .params
        .iter()
        .map(|p| ParamSpec::inferred(p.name.clone(), &p.shape))
        .collect();
    let res = group::resolve(&specs, &cfg.grouped());
    let mut opt = optim::build_with_policies(cfg.optimizer, &shapes, &cfg.optim, &res.tensor);
    let mut params = graph.init_params(cfg.seed);
    drop(graph);
    drop(rt);

    let (result_tx, result_rx) = mpsc::channel::<Result<FromWorker>>();
    let mut cmd_txs = Vec::new();
    let mut handles = Vec::new();
    for w in 0..n_workers {
        let (cmd_tx, cmd_rx) = mpsc::channel::<ToWorker>();
        cmd_txs.push(cmd_tx);
        let result_tx = result_tx.clone();
        let artifact_dir = artifact_dir.to_string();
        let artifact = cfg.artifact.clone();
        let seed = cfg.seed;
        handles.push(thread::spawn(move || {
            let run = || -> Result<()> {
                let rt = Runtime::open(&artifact_dir)?;
                let graph = TrainGraph::load(&rt, &artifact)?;
                // Each worker streams a disjoint shard (distinct seed).
                let mut source = BatchSource::for_spec(graph.spec(), seed ^ (w as u64) << 17)?;
                let mut grads = Vec::new();
                loop {
                    match cmd_rx.recv() {
                        Ok(ToWorker::Params(params)) => {
                            let batch = source.next()?;
                            let loss = graph.loss_and_grads(&params, &batch, &mut grads)?;
                            result_tx
                                .send(Ok(FromWorker {
                                    worker: w,
                                    loss,
                                    grads: std::mem::take(&mut grads),
                                }))
                                .ok();
                        }
                        Ok(ToWorker::Stop) | Err(_) => return Ok(()),
                    }
                }
            };
            if let Err(e) = run() {
                result_tx.send(Err(anyhow!("worker {w}: {e}"))).ok();
            }
        }));
    }

    let mut losses = Vec::with_capacity(cfg.steps as usize);
    let mut avg: Vec<Tensor> = shapes.iter().map(|s| Tensor::zeros(s)).collect();
    for _step in 0..cfg.steps {
        for tx in &cmd_txs {
            tx.send(ToWorker::Params(params.clone())).map_err(|_| anyhow!("worker died"))?;
        }
        avg.iter_mut().for_each(|t| t.fill(0.0));
        let mut loss_sum = 0.0f32;
        for _ in 0..n_workers {
            let msg = result_rx.recv().map_err(|_| anyhow!("workers gone"))??;
            loss_sum += msg.loss;
            for (a, g) in avg.iter_mut().zip(&msg.grads) {
                a.axpy(1.0 / n_workers as f32, g);
            }
            let _ = msg.worker;
        }
        opt.step(&mut params, &avg);
        losses.push(loss_sum / n_workers as f32);
    }
    for tx in &cmd_txs {
        tx.send(ToWorker::Stop).ok();
    }
    for h in handles {
        h.join().map_err(|_| anyhow!("worker panicked"))?;
    }
    Ok(losses)
}
