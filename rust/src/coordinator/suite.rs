//! Suite scheduler: expand a [`SuiteConfig`] run matrix and schedule the
//! independent cells over a backend — the in-process
//! [`workers::fan_out_recover`] thread pool, or the
//! [`remote`](crate::coordinator::remote) dispatcher when the worker
//! spec names `repro worker` daemons (`workers = "remote:host:port,…"`).
//!
//! Each expanded cell trains one `(model, optimizer, seed)` combination
//! into `<out_dir>/<suite>/<run>/` with the same artifacts a standalone
//! `repro train` run leaves (`metrics.{jsonl,csv}`, `summary.json`).
//! Three properties make suites safe to run repeatedly, on any backend:
//!
//! * **Resume-aware re-entry** — a cell whose `summary.json` already
//!   exists is skipped (`CellStatus::Skipped`), so an interrupted suite
//!   picks up where it left off and a completed suite is a no-op that
//!   just re-renders the report from identical inputs (this is what
//!   makes `docs/RESULTS.md` reproducible byte-for-byte). The cache is
//!   purely on-disk state, so it carries *across backends*: cells a
//!   remote run completed are skipped by a local re-run and vice versa.
//! * **Failure isolation** — a cell that errors, diverges or panics
//!   writes a `FAILED` marker (first line = the error) and the suite
//!   carries on; failed cells are retried on the next invocation and
//!   listed in the report instead of poisoning the aggregate tables.
//! * **Independence** — cells never share mutable state: artifact cells
//!   open their own [`Runtime`] inside the worker (exactly like the
//!   data-parallel workers), synthetic cells are pure Rust.
//!
//! Statuses are committed in expansion order regardless of which worker
//! finished first, and the report generator reads only the on-disk
//! per-cell verdicts — so `docs/RESULTS.md` / `BENCH_suite.json` bytes
//! never depend on the backend or on completion timing.

use anyhow::Result;
use std::path::{Path, PathBuf};

use crate::coordinator::config::{SuiteCell, SuiteConfig, WorkerSpec};
use crate::coordinator::{experiments, remote, workers};
use crate::runtime::Runtime;
use crate::train::metrics;

/// Scheduler knobs for one `repro suite` invocation.
#[derive(Clone, Debug)]
pub struct SuiteOptions {
    /// Re-run cells even when their `summary.json` already exists.
    pub force: bool,
    /// CLI override for the suite's worker spec (`--workers
    /// "N | local:N | remote:HOST:PORT,…"`); `None` = use `[suite]
    /// workers`.
    pub workers: Option<WorkerSpec>,
    /// AOT artifacts directory for artifact-backed cells.
    pub artifacts_dir: String,
    /// Remote backend: a worker whose in-flight cells make no observable
    /// progress for this long is declared dead and its cells are
    /// re-dispatched to the survivors.
    pub lease_timeout_ms: u64,
}

impl Default for SuiteOptions {
    fn default() -> Self {
        Self {
            force: false,
            workers: None,
            artifacts_dir: "artifacts".into(),
            lease_timeout_ms: 10_000,
        }
    }
}

/// What happened to one expanded cell.
#[derive(Clone, Debug, PartialEq)]
pub enum CellStatus {
    /// Trained in this invocation and left a finite-loss summary.
    Ran,
    /// `summary.json` already existed — reused (resume-aware re-entry).
    Skipped,
    /// Errored or diverged; the note is mirrored into the `FAILED`
    /// marker file and the rest of the suite kept running.
    Failed(String),
}

/// The per-cell outcomes of one suite invocation, in expansion order.
pub struct SuiteOutcome {
    /// `<out_dir>/<suite>/` — where the cells (and usually the report)
    /// live.
    pub suite_dir: PathBuf,
    /// One `(cell, status)` per expanded cell.
    pub cells: Vec<(SuiteCell, CellStatus)>,
}

impl SuiteOutcome {
    /// `(ran, skipped, failed)` cell counts.
    pub fn counts(&self) -> (usize, usize, usize) {
        let mut c = (0, 0, 0);
        for (_, s) in &self.cells {
            match s {
                CellStatus::Ran => c.0 += 1,
                CellStatus::Skipped => c.1 += 1,
                CellStatus::Failed(_) => c.2 += 1,
            }
        }
        c
    }
}

/// Expand and run a suite. Errors only on setup problems (bad expansion,
/// unwritable out dir) — per-cell failures are isolated into
/// [`CellStatus::Failed`].
pub fn run_suite(suite: &SuiteConfig, opts: &SuiteOptions) -> Result<SuiteOutcome> {
    let cells = suite.expand()?;
    let suite_dir = Path::new(&suite.out_dir).join(&suite.name);
    std::fs::create_dir_all(&suite_dir)?;
    let spec = opts.workers.clone().unwrap_or_else(|| suite.workers.clone());
    let total = cells.len();
    println!(
        "[suite {}] {total} cells over {} -> {}",
        suite.name,
        spec.describe(),
        suite_dir.display()
    );
    let statuses = if spec.is_local_only() {
        // A panicking cell is recovered into a FAILED marker instead of
        // tearing down the pool (same contract as the remote workers).
        workers::fan_out_recover(
            cells.clone(),
            spec.local.max(1),
            |i, cell| run_cell(i, total, &cell, opts),
            |i, note| {
                let cell = &cells[i];
                fail_cell(
                    &cell_tag(i, total, &cell.run),
                    &cell_dir(cell),
                    format!("cell worker panicked: {note}"),
                )
            },
        )
    } else {
        remote::dispatch::run_dispatched(&cells, &spec, opts)?
    };
    Ok(SuiteOutcome { suite_dir, cells: cells.into_iter().zip(statuses).collect() })
}

/// `[suite] (i/total) <run>` — the per-cell log prefix.
pub(crate) fn cell_tag(idx: usize, total: usize, run: &str) -> String {
    format!("[suite] ({}/{total}) {run}", idx + 1)
}

/// `<out_dir>/<suite>/<run>/` for an expanded cell.
pub(crate) fn cell_dir(cell: &SuiteCell) -> PathBuf {
    Path::new(&cell.cfg.out_dir).join(&cell.cfg.name)
}

/// The re-entry cache check: a cell is cached when its `summary.json`
/// exists and no `FAILED` marker flags it for retry. Pure on-disk
/// state — both backends (and the remote dispatcher's re-dispatch
/// path) consult the same verdict files.
pub(crate) fn cell_cached(cell: &SuiteCell, force: bool) -> bool {
    let summary = metrics::summary_path(&cell.cfg.out_dir, &cell.cfg.name);
    !force && summary.exists() && !cell_dir(cell).join("FAILED").exists()
}

fn run_cell(idx: usize, total: usize, cell: &SuiteCell, opts: &SuiteOptions) -> CellStatus {
    let tag = cell_tag(idx, total, &cell.run);
    if cell_cached(cell, opts.force) {
        println!("{tag}: cached (summary.json exists — use --force to re-run)");
        return CellStatus::Skipped;
    }
    if opts.force {
        let _ = std::fs::remove_file(metrics::summary_path(&cell.cfg.out_dir, &cell.cfg.name));
    }
    execute_cell(&tag, cell, &opts.artifacts_dir)
}

/// Train one cell (no cache check — the caller decided). Shared by the
/// local pool, the remote dispatcher's local lanes, and the `repro
/// worker` daemon, which all leave identical on-disk artifacts.
pub(crate) fn execute_cell(tag: &str, cell: &SuiteCell, artifacts_dir: &str) -> CellStatus {
    let dir = cell_dir(cell);
    // A retry owns the cell directory's verdict files again.
    let _ = std::fs::remove_file(dir.join("FAILED"));
    let result = if let Some(inv) = cell.model.strip_prefix("synthetic:") {
        experiments::run_synthetic_experiment(&cell.cfg, inv)
    } else {
        Runtime::open(artifacts_dir).and_then(|rt| experiments::run_experiment(&rt, &cell.cfg))
    };
    match result {
        Ok(s) if s.final_loss.is_finite() => {
            println!(
                "{tag}: ok — loss {:.4} -> {:.4}, {:.2} ms/step",
                s.first_loss, s.final_loss, s.mean_step_ms
            );
            CellStatus::Ran
        }
        Ok(s) => fail_cell(tag, &dir, format!("diverged: non-finite loss after {} steps", s.steps)),
        Err(e) => fail_cell(tag, &dir, format!("{e:#}")),
    }
}

pub(crate) fn fail_cell(tag: &str, dir: &Path, note: String) -> CellStatus {
    println!("{tag}: FAILED — {note}");
    // Best-effort marker: the suite keeps going even if the cell dir is
    // unwritable (the report then lists the cell as incomplete instead).
    let _ = std::fs::create_dir_all(dir);
    let _ = std::fs::write(dir.join("FAILED"), note.clone() + "\n");
    CellStatus::Failed(note)
}
