//! Suite scheduler: expand a [`SuiteConfig`] run matrix and schedule the
//! independent cells over the [`workers::fan_out`] pool.
//!
//! Each expanded cell trains one `(model, optimizer, seed)` combination
//! into `<out_dir>/<suite>/<run>/` with the same artifacts a standalone
//! `repro train` run leaves (`metrics.{jsonl,csv}`, `summary.json`).
//! Three properties make suites safe to run repeatedly:
//!
//! * **Resume-aware re-entry** — a cell whose `summary.json` already
//!   exists is skipped (`CellStatus::Skipped`), so an interrupted suite
//!   picks up where it left off and a completed suite is a no-op that
//!   just re-renders the report from identical inputs (this is what
//!   makes `docs/RESULTS.md` reproducible byte-for-byte).
//! * **Failure isolation** — a cell that errors or diverges writes a
//!   `FAILED` marker (first line = the error) and the suite carries on;
//!   failed cells are retried on the next invocation and listed in the
//!   report instead of poisoning the aggregate tables.
//! * **Independence** — cells never share mutable state: artifact cells
//!   open their own [`Runtime`] inside the worker (exactly like the
//!   data-parallel workers), synthetic cells are pure Rust.

use anyhow::Result;
use std::path::{Path, PathBuf};

use crate::coordinator::config::{SuiteCell, SuiteConfig};
use crate::coordinator::{experiments, workers};
use crate::runtime::Runtime;
use crate::train::metrics;

/// Scheduler knobs for one `repro suite` invocation.
#[derive(Clone, Debug)]
pub struct SuiteOptions {
    /// Re-run cells even when their `summary.json` already exists.
    pub force: bool,
    /// Worker-pool width override (`0` = use `[suite] workers`).
    pub workers: usize,
    /// AOT artifacts directory for artifact-backed cells.
    pub artifacts_dir: String,
}

impl Default for SuiteOptions {
    fn default() -> Self {
        Self { force: false, workers: 0, artifacts_dir: "artifacts".into() }
    }
}

/// What happened to one expanded cell.
#[derive(Clone, Debug, PartialEq)]
pub enum CellStatus {
    /// Trained in this invocation and left a finite-loss summary.
    Ran,
    /// `summary.json` already existed — reused (resume-aware re-entry).
    Skipped,
    /// Errored or diverged; the note is mirrored into the `FAILED`
    /// marker file and the rest of the suite kept running.
    Failed(String),
}

/// The per-cell outcomes of one suite invocation, in expansion order.
pub struct SuiteOutcome {
    /// `<out_dir>/<suite>/` — where the cells (and usually the report)
    /// live.
    pub suite_dir: PathBuf,
    /// One `(cell, status)` per expanded cell.
    pub cells: Vec<(SuiteCell, CellStatus)>,
}

impl SuiteOutcome {
    /// `(ran, skipped, failed)` cell counts.
    pub fn counts(&self) -> (usize, usize, usize) {
        let mut c = (0, 0, 0);
        for (_, s) in &self.cells {
            match s {
                CellStatus::Ran => c.0 += 1,
                CellStatus::Skipped => c.1 += 1,
                CellStatus::Failed(_) => c.2 += 1,
            }
        }
        c
    }
}

/// Expand and run a suite. Errors only on setup problems (bad expansion,
/// unwritable out dir) — per-cell failures are isolated into
/// [`CellStatus::Failed`].
pub fn run_suite(suite: &SuiteConfig, opts: &SuiteOptions) -> Result<SuiteOutcome> {
    let cells = suite.expand()?;
    let suite_dir = Path::new(&suite.out_dir).join(&suite.name);
    std::fs::create_dir_all(&suite_dir)?;
    let n_workers = if opts.workers > 0 { opts.workers } else { suite.workers };
    let total = cells.len();
    println!(
        "[suite {}] {total} cells over {n_workers} worker(s) -> {}",
        suite.name,
        suite_dir.display()
    );
    let statuses = workers::fan_out(cells.clone(), n_workers, |i, cell| {
        run_cell(i, total, &cell, opts)
    });
    Ok(SuiteOutcome { suite_dir, cells: cells.into_iter().zip(statuses).collect() })
}

fn run_cell(idx: usize, total: usize, cell: &SuiteCell, opts: &SuiteOptions) -> CellStatus {
    let tag = format!("[suite] ({}/{total}) {}", idx + 1, cell.run);
    let dir = Path::new(&cell.cfg.out_dir).join(&cell.cfg.name);
    let summary = metrics::summary_path(&cell.cfg.out_dir, &cell.cfg.name);
    let failed_marker = dir.join("FAILED");
    if !opts.force && summary.exists() && !failed_marker.exists() {
        println!("{tag}: cached (summary.json exists — use --force to re-run)");
        return CellStatus::Skipped;
    }
    // A retry owns the cell directory's verdict files again.
    let _ = std::fs::remove_file(&failed_marker);
    if opts.force {
        let _ = std::fs::remove_file(&summary);
    }
    let result = if let Some(inv) = cell.model.strip_prefix("synthetic:") {
        experiments::run_synthetic_experiment(&cell.cfg, inv)
    } else {
        Runtime::open(&opts.artifacts_dir)
            .and_then(|rt| experiments::run_experiment(&rt, &cell.cfg))
    };
    match result {
        Ok(s) if s.final_loss.is_finite() => {
            println!(
                "{tag}: ok — loss {:.4} -> {:.4}, {:.2} ms/step",
                s.first_loss, s.final_loss, s.mean_step_ms
            );
            CellStatus::Ran
        }
        Ok(s) => fail_cell(&tag, &dir, format!("diverged: non-finite loss after {} steps", s.steps)),
        Err(e) => fail_cell(&tag, &dir, format!("{e:#}")),
    }
}

fn fail_cell(tag: &str, dir: &Path, note: String) -> CellStatus {
    println!("{tag}: FAILED — {note}");
    // Best-effort marker: the suite keeps going even if the cell dir is
    // unwritable (the report then lists the cell as incomplete instead).
    let _ = std::fs::create_dir_all(dir);
    let _ = std::fs::write(dir.join("FAILED"), note.clone() + "\n");
    CellStatus::Failed(note)
}
