//! The experiment coordinator: config, experiment registry, launcher,
//! the multi-worker pools, and the suite/report subsystem.
//!
//! Every table and figure of the paper maps to a runner here (see
//! DESIGN.md §3); `repro <experiment>` regenerates it. The coordinator
//! owns process topology (worker threads for data-parallel gradient
//! averaging and for suite-cell fan-out), metrics, and the CLI surface.
//!
//! The suite subsystem turns the one-run-at-a-time harness declarative:
//! [`config::SuiteConfig`] parses a `[[suite.run]]` sweep file,
//! [`suite::run_suite`] schedules the expanded optimizer × model × seed
//! matrix over [`workers::fan_out_recover`] with failure isolation and
//! resume-aware re-entry, and [`report`] aggregates the per-cell
//! summaries into the paper-style memory/quality/throughput tables
//! (`docs/RESULTS.md`, `BENCH_suite.json`).
//!
//! The [`remote`] subsystem scales the same suites past one machine:
//! `repro worker` daemons execute cells shipped over the `SMMFCELL`
//! wire protocol, and a `workers = "remote:host:port,…"` spec swaps the
//! thread pool for the submit/poll dispatcher — same cells, same
//! on-disk artifacts, byte-identical reports.

pub mod config;
pub mod experiments;
pub mod remote;
pub mod report;
pub mod suite;
pub mod workers;

pub use config::{ExperimentConfig, SuiteConfig, WorkerSpec};
