//! The experiment coordinator: config, experiment registry, launcher,
//! the multi-worker pools, and the suite/report subsystem.
//!
//! Every table and figure of the paper maps to a runner here (see
//! DESIGN.md §3); `repro <experiment>` regenerates it. The coordinator
//! owns process topology (worker threads for data-parallel gradient
//! averaging and for suite-cell fan-out), metrics, and the CLI surface.
//!
//! The suite subsystem turns the one-run-at-a-time harness declarative:
//! [`config::SuiteConfig`] parses a `[[suite.run]]` sweep file,
//! [`suite::run_suite`] schedules the expanded optimizer × model × seed
//! matrix over [`workers::fan_out`] with failure isolation and
//! resume-aware re-entry, and [`report`] aggregates the per-cell
//! summaries into the paper-style memory/quality/throughput tables
//! (`docs/RESULTS.md`, `BENCH_suite.json`).

pub mod config;
pub mod experiments;
pub mod report;
pub mod suite;
pub mod workers;

pub use config::{ExperimentConfig, SuiteConfig};
