//! The experiment coordinator: config, experiment registry, launcher and
//! the multi-worker data-parallel runtime.
//!
//! Every table and figure of the paper maps to a runner here (see
//! DESIGN.md §3); `repro <experiment>` regenerates it. The coordinator
//! owns process topology (worker threads for data-parallel gradient
//! averaging), metrics, and the CLI surface.

pub mod config;
pub mod experiments;
pub mod workers;

pub use config::ExperimentConfig;
