//! Experiment runners — one per paper table/figure (DESIGN.md §3).

use anyhow::{anyhow, bail, Result};
use std::time::Instant;

use crate::coordinator::config::ExperimentConfig;
use crate::data::{CharLmDataset, SyntheticImages, TINY_CORPUS};
use crate::models::inventory_by_name;
use crate::optim::group::{self, ParamSpec};
use crate::optim::{self, memory, OptKind, OptimConfig};
use crate::runtime::{lit_f32, lit_i32, ArtifactSpec, Runtime};
use crate::tensor::Tensor;
use crate::train::{checkpoint, RunLogger, TrainGraph, Trainer};
use crate::util::fmt;
use crate::util::rng::Pcg32;

// ---------------------------------------------------------------------------
// Batch sources (dataset substitution per DESIGN.md §4)
// ---------------------------------------------------------------------------

/// Produces batches of input literals matching an artifact's batch inputs.
pub enum BatchSource {
    Mlp { rng: Pcg32, batch: usize, in_dim: usize, classes: usize },
    Cnn { gen: SyntheticImages, batch: usize },
    Lm { ds: CharLmDataset, batch: usize },
    Lora { ds: CharLmDataset, batch: usize, base: Vec<xla::Literal> },
}

impl BatchSource {
    /// Build the right source for an artifact from its manifest metadata.
    pub fn for_spec(spec: &ArtifactSpec, seed: u64) -> Result<BatchSource> {
        let meta = |k: &str| -> Result<usize> {
            spec.meta
                .get(k)
                .map(|&v| v as usize)
                .ok_or_else(|| anyhow!("artifact missing meta.{k}"))
        };
        Ok(match spec.model.as_str() {
            "mlp" => BatchSource::Mlp {
                rng: Pcg32::new(seed),
                batch: meta("batch")?,
                in_dim: meta("in_dim")?,
                classes: meta("classes")?,
            },
            "cnn" => BatchSource::Cnn {
                gen: SyntheticImages::new(meta("classes")?, meta("image")?, 0.3, seed),
                batch: meta("batch")?,
            },
            "lm" => BatchSource::Lm {
                ds: CharLmDataset::new(TINY_CORPUS, meta("seq_len")?, seed),
                batch: meta("batch")?,
            },
            "lora_lm" => {
                // Frozen base weights are artifact *inputs*; generate a
                // fixed pseudo-pretrained base once (name-driven init).
                let n_batch_io = 2; // tokens, targets
                let base = spec.inputs[spec.params.len() + n_batch_io..]
                    .iter()
                    .map(|io| {
                        let numel: usize = io.shape.iter().product();
                        let mut rng = Pcg32::new(seed ^ 0xba5e);
                        let data: Vec<f32> = if io.name.ends_with("_g") {
                            vec![1.0; numel]
                        } else if io.name.ends_with("_b") {
                            vec![0.0; numel]
                        } else {
                            (0..numel).map(|_| rng.normal() * 0.02).collect()
                        };
                        lit_f32(&io.shape, &data)
                    })
                    .collect::<Result<Vec<_>>>()?;
                BatchSource::Lora {
                    ds: CharLmDataset::new(TINY_CORPUS, meta("seq_len")?, seed),
                    batch: meta("batch")?,
                    base,
                }
            }
            other => bail!("no batch source for model kind {other:?}"),
        })
    }

    /// Data-stream RNG snapshot `(state, inc)` — written into v2
    /// checkpoints so a resumed run replays the exact batch sequence.
    pub fn rng_state(&self) -> (u64, u64) {
        match self {
            BatchSource::Mlp { rng, .. } => rng.state(),
            BatchSource::Cnn { gen, .. } => gen.rng_state(),
            BatchSource::Lm { ds, .. } | BatchSource::Lora { ds, .. } => ds.rng_state(),
        }
    }

    /// Restore a [`BatchSource::rng_state`] snapshot.
    pub fn set_rng_state(&mut self, state: u64, inc: u64) {
        match self {
            BatchSource::Mlp { rng, .. } => *rng = Pcg32::from_state(state, inc),
            BatchSource::Cnn { gen, .. } => gen.set_rng_state(state, inc),
            BatchSource::Lm { ds, .. } | BatchSource::Lora { ds, .. } => {
                ds.set_rng_state(state, inc)
            }
        }
    }

    pub fn next(&mut self) -> Result<Vec<xla::Literal>> {
        match self {
            BatchSource::Mlp { rng, batch, in_dim, classes } => {
                // Class-conditional Gaussian blobs: mean pattern per class.
                let (b, d, c) = (*batch, *in_dim, *classes);
                let mut x = Vec::with_capacity(b * d);
                let mut y = Vec::with_capacity(b);
                for _ in 0..b {
                    let cls = rng.below(c);
                    y.push(cls as i32);
                    for j in 0..d {
                        let mean = ((cls * 7 + j) % 5) as f32 - 2.0;
                        x.push(0.7 * mean + 0.5 * rng.normal());
                    }
                }
                Ok(vec![lit_f32(&[b, d], &x)?, lit_i32(&[b], &y)?])
            }
            BatchSource::Cnn { gen, batch } => {
                let (mut px, mut ys) = (Vec::new(), Vec::new());
                gen.sample_batch(*batch, &mut px, &mut ys);
                let s = gen.size;
                Ok(vec![lit_f32(&[*batch, 3, s, s], &px)?, lit_i32(&[*batch], &ys)?])
            }
            BatchSource::Lm { ds, batch } => {
                let (mut x, mut y) = (Vec::new(), Vec::new());
                ds.sample_batch(*batch, &mut x, &mut y);
                let t = ds.seq_len;
                Ok(vec![lit_i32(&[*batch, t], &x)?, lit_i32(&[*batch, t], &y)?])
            }
            BatchSource::Lora { ds, batch, base } => {
                let (mut x, mut y) = (Vec::new(), Vec::new());
                ds.sample_batch(*batch, &mut x, &mut y);
                let t = ds.seq_len;
                let mut out = vec![lit_i32(&[*batch, t], &x)?, lit_i32(&[*batch, t], &y)?];
                out.extend(base.iter().cloned());
                Ok(out)
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Generic training experiment
// ---------------------------------------------------------------------------

pub struct RunSummary {
    pub name: String,
    pub optimizer: String,
    pub steps: u64,
    pub first_loss: f32,
    pub final_loss: f32,
    pub mean_step_ms: f64,
    pub opt_state_bytes: u64,
}

/// Serialize a run summary to the `summary.json` schema the suite
/// report generator (`coordinator::report`) aggregates: the RunSummary
/// fields plus the sweep coordinates (`model`, `seed`), the trainable
/// `param_count`, and the recipe knobs that silently shape trajectories.
fn summary_json(
    s: &RunSummary,
    cfg: &ExperimentConfig,
    model: &str,
    param_count: u64,
    param_groups: usize,
) -> crate::util::json::Json {
    crate::util::json::ObjBuilder::new()
        .str("name", &s.name)
        .str("optimizer", &s.optimizer)
        .str("model", model)
        .num("seed", cfg.seed as f64)
        .num("steps", s.steps as f64)
        .num("param_count", param_count as f64)
        .num("first_loss", s.first_loss as f64)
        .num("final_loss", s.final_loss as f64)
        .num("mean_step_ms", s.mean_step_ms)
        .num("opt_state_bytes", s.opt_state_bytes as f64)
        .bool("bias_correction", cfg.optim.bias_correction)
        .num("weight_decay", cfg.optim.weight_decay as f64)
        .num("param_groups", param_groups as f64)
        .build()
}

/// Train one configuration through the AOT path, logging to
/// `runs/<name>/`. This is the workhorse behind fig1/fig2/fig4/e2e.
///
/// With `cfg.resume` set the full training state (params, step, data-RNG
/// position, optimizer momenta) is restored from the checkpoint first;
/// with `cfg.save_every > 0` a `runs/<name>/checkpoint.bin` is written
/// every N steps and at the end, so long runs survive restarts with
/// bit-identical trajectories.
pub fn run_experiment(rt: &Runtime, cfg: &ExperimentConfig) -> Result<RunSummary> {
    let graph = TrainGraph::load(rt, &cfg.artifact)?;
    // Grouped construction: roles inferred from the artifact's HF-style
    // tensor names, group matchers resolved once, and the resolved
    // fingerprint registered with the trainer (checkpoint CONFIG
    // section + resume cross-check).
    let specs: Vec<ParamSpec> = graph
        .spec()
        .params
        .iter()
        .map(|p| ParamSpec::inferred(p.name.clone(), &p.shape))
        .collect();
    let gcfg = cfg.grouped();
    let res = group::resolve(&specs, &gcfg);
    let shapes = graph.param_shapes();
    let opt = optim::build_with_policies(cfg.optimizer, &shapes, &cfg.optim, &res.tensor);
    if !cfg.groups.is_empty() {
        for g in res.groups.iter().filter(|g| g.tensors > 0) {
            println!(
                "[{}] group {:<12} {:>3} tensors  {:>10} params  lr_scale {}  wd {}  state {}{}",
                cfg.name,
                g.name,
                g.tensors,
                fmt::count(g.params),
                g.lr_scale,
                g.weight_decay,
                g.state.name(),
                if g.frozen { "  (frozen)" } else { "" },
            );
        }
    }
    let mut source = BatchSource::for_spec(graph.spec(), cfg.seed ^ 0xda7a)?;
    let mut trainer = Trainer::new(graph, opt, cfg.seed, cfg.optim.lr, cfg.schedule.clone());
    trainer.set_config_section(checkpoint::ConfigSection::from_config(&cfg.optim, &res));
    if let Some(path) = &cfg.resume {
        let rng = trainer.resume_from(std::path::Path::new(path))?;
        if let Some((state, inc)) = rng {
            source.set_rng_state(state, inc);
        }
        println!("[{}] resumed from {path} at step {}", cfg.name, trainer.step);
        if trainer.step >= cfg.steps {
            println!(
                "[{}] checkpoint step {} >= configured steps {} — nothing to train",
                cfg.name, trainer.step, cfg.steps
            );
        }
    }
    // Resumed runs append so the pre-checkpoint curves survive restarts
    // (rows logged after the checkpoint step are pruned — the resumed
    // run re-logs them).
    let mut logger = if cfg.resume.is_some() {
        RunLogger::append(&cfg.out_dir, &cfg.name, trainer.step)?
    } else {
        RunLogger::create(&cfg.out_dir, &cfg.name)?
    };
    let ckpt_path = logger.dir.join("checkpoint.bin");

    let start_step = trainer.step;
    let mut first_loss = f32::NAN;
    let mut final_loss = f32::NAN;
    let t0 = Instant::now();
    for step in start_step + 1..=cfg.steps {
        let batch = source.next()?;
        let loss = trainer.train_step(&batch)?;
        if step == start_step + 1 {
            first_loss = loss;
        }
        final_loss = loss;
        if step % cfg.log_every == 0 || step == start_step + 1 || step == cfg.steps {
            let ms = t0.elapsed().as_secs_f64() * 1e3 / (step - start_step) as f64;
            logger.log(
                step,
                loss,
                &[
                    ("ppl", (loss as f64).exp()),
                    ("step_ms", ms),
                    ("opt_mib", fmt::mib(trainer.optimizer_state_bytes())),
                ],
            )?;
        }
        if cfg.save_every > 0 && (step % cfg.save_every == 0 || step == cfg.steps) {
            trainer.save_checkpoint(&ckpt_path, Some(source.rng_state()))?;
        }
    }
    logger.flush()?;
    let summary = RunSummary {
        name: cfg.name.clone(),
        optimizer: cfg.optimizer.name().into(),
        steps: cfg.steps,
        first_loss,
        final_loss,
        mean_step_ms: t0.elapsed().as_secs_f64() * 1e3
            / cfg.steps.saturating_sub(start_step).max(1) as f64,
        opt_state_bytes: trainer.optimizer_state_bytes(),
    };
    let param_count: u64 = shapes.iter().map(|s| s.iter().product::<usize>() as u64).sum();
    logger.write_summary(&summary_json(
        &summary,
        cfg,
        &cfg.artifact,
        param_count,
        res.groups.iter().filter(|g| g.tensors > 0).count(),
    ))?;
    Ok(summary)
}

// ---------------------------------------------------------------------------
// Synthetic workload (artifact-free suite cells)
// ---------------------------------------------------------------------------

/// Train a `synthetic:<inventory>` suite cell: a noisy quadratic well
/// over a real model inventory, driven entirely in Rust (no AOT
/// artifacts, no PJRT).
///
/// The objective is `L(θ) = Σ ½(θ − θ*)² / N` with a fixed random
/// target `θ*`; each step feeds the optimizer the per-element residual
/// gradient `g = (θ − θ*) + σ·ξ` with deterministic Gaussian noise `ξ`
/// (σ = 0.01) from the cell's data RNG. That is enough to exercise the
/// full optimizer state machinery — matricized momenta, sign planes,
/// group policies, the parallel step engine — with bit-reproducible
/// trajectories per seed, so suite quality cells aggregate cleanly and
/// memory/throughput cells measure the real optimizer hot path.
///
/// Artifacts mirror [`run_experiment`]: `runs/<name>/metrics.{jsonl,csv}`
/// plus `summary.json`. Checkpointing (`save_every`) is not wired for
/// synthetic cells — runs are cheap to restart from scratch.
pub fn run_synthetic_experiment(cfg: &ExperimentConfig, inventory: &str) -> Result<RunSummary> {
    let inv = inventory_by_name(inventory)
        .ok_or_else(|| anyhow!("unknown synthetic inventory {inventory}"))?;
    let specs = inv.param_specs();
    let shapes = inv.shapes();
    let gcfg = cfg.grouped();
    let res = group::resolve(&specs, &gcfg);
    let mut opt = optim::build_with_policies(cfg.optimizer, &shapes, &cfg.optim, &res.tensor);

    // Deterministic init: params at the origin, targets ~ N(0, 0.5²).
    let mut params: Vec<Tensor> = shapes.iter().map(|s| Tensor::zeros(s)).collect();
    let mut target_rng = Pcg32::new(cfg.seed ^ 0x7a67);
    let targets: Vec<Tensor> = shapes
        .iter()
        .map(|s| {
            let mut t = Tensor::zeros(s);
            target_rng.fill_normal(t.data_mut(), 0.5);
            t
        })
        .collect();
    let mut noise = Pcg32::new(cfg.seed ^ 0xda7a);
    let n_total: f64 = shapes.iter().map(|s| s.iter().product::<usize>() as f64).sum();

    let mut logger = RunLogger::create(&cfg.out_dir, &cfg.name)?;
    let mut grads: Vec<Tensor> = shapes.iter().map(|s| Tensor::zeros(s)).collect();
    let (mut first_loss, mut final_loss) = (f32::NAN, f32::NAN);
    let t0 = Instant::now();
    for step in 1..=cfg.steps {
        let mut loss_acc = 0.0f64;
        for ((p, t), g) in params.iter().zip(&targets).zip(grads.iter_mut()) {
            let (pd, td, gd) = (p.data(), t.data(), g.data_mut());
            for i in 0..pd.len() {
                let r = pd[i] - td[i];
                loss_acc += 0.5 * (r as f64) * (r as f64);
                gd[i] = r + 0.01 * noise.normal();
            }
        }
        let loss = (loss_acc / n_total) as f32;
        if step == 1 {
            first_loss = loss;
        }
        final_loss = loss;
        opt.set_lr(cfg.schedule.at(cfg.optim.lr, step));
        opt.step(&mut params, &grads);
        if step % cfg.log_every == 0 || step == 1 || step == cfg.steps {
            let ms = t0.elapsed().as_secs_f64() * 1e3 / step as f64;
            logger.log(
                step,
                loss,
                &[("step_ms", ms), ("opt_mib", fmt::mib(opt.state_bytes()))],
            )?;
        }
    }
    logger.flush()?;
    let summary = RunSummary {
        name: cfg.name.clone(),
        optimizer: cfg.optimizer.name().into(),
        steps: cfg.steps,
        first_loss,
        final_loss,
        mean_step_ms: t0.elapsed().as_secs_f64() * 1e3 / cfg.steps.max(1) as f64,
        opt_state_bytes: opt.state_bytes(),
    };
    logger.write_summary(&summary_json(
        &summary,
        cfg,
        &format!("synthetic:{inventory}"),
        n_total as u64,
        res.groups.iter().filter(|g| g.tensors > 0).count(),
    ))?;
    Ok(summary)
}

/// Run a figure-style comparison: the same workload under several
/// optimizers; returns one summary per optimizer.
pub fn run_comparison(
    rt: &Runtime,
    base: &ExperimentConfig,
    kinds: &[OptKind],
    group: &str,
) -> Result<Vec<RunSummary>> {
    let mut out = Vec::new();
    for kind in kinds {
        let mut cfg = base.clone();
        // Shared recipe knobs (lr, γ, weight decay, engine threads)
        // follow the base config; per-optimizer ε/β defaults come from
        // the paper (Appendix L). Same rule as the suite expander.
        cfg.retarget_optimizer(*kind);
        cfg.name = format!("{group}/{}", kind.name());
        println!("[{} | {}] {} steps on {}", group, kind.name(), cfg.steps, cfg.artifact);
        let s = run_experiment(rt, &cfg)?;
        println!(
            "    loss {:.4} -> {:.4}   {:.1} ms/step   opt state {}",
            s.first_loss,
            s.final_loss,
            s.mean_step_ms,
            fmt::bytes(s.opt_state_bytes)
        );
        out.push(s);
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Memory tables (Tables 1-4, 6-13 memory columns)
// ---------------------------------------------------------------------------

pub struct MemoryRow {
    pub model: String,
    pub optimizer: String,
    pub params: u64,
    pub opt_bytes: u64,
    pub e2e_bytes: u64,
    /// On-disk bytes of the optimizer-state section of a `SMMFCKPT` v2
    /// checkpoint (native serialization — factorized state stays small
    /// on disk too).
    pub ckpt_bytes: u64,
}

/// Compute the paper's (optimizer memory, end-to-end memory) cells for a
/// set of model inventories × the five optimizers.
pub fn memory_rows(models: &[&str]) -> Result<Vec<MemoryRow>> {
    let mut rows = Vec::new();
    for name in models {
        let inv = inventory_by_name(name).ok_or_else(|| anyhow!("unknown inventory {name}"))?;
        let shapes = inv.shapes();
        for kind in OptKind::all() {
            let cfg = OptimConfig::paper_defaults(kind);
            let r = memory::report(kind, &shapes, &cfg);
            rows.push(MemoryRow {
                model: name.to_string(),
                optimizer: kind.name().into(),
                params: r.param_count,
                opt_bytes: r.opt_bytes,
                // e2e additionally includes frozen weights (LoRA case).
                e2e_bytes: r.e2e_bytes + inv.frozen_bytes,
                ckpt_bytes: r.ckpt_opt_bytes,
            });
        }
    }
    Ok(rows)
}

pub fn render_memory_table(title: &str, rows: &[MemoryRow]) -> String {
    let body: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.model.clone(),
                r.optimizer.clone(),
                fmt::count(r.params),
                format!("{:.1}", fmt::mib(r.opt_bytes)),
                format!("{:.1}", fmt::mib(r.ckpt_bytes)),
                format!("{:.1}", fmt::mib(r.e2e_bytes)),
                format!("{:.3}", fmt::gib(r.e2e_bytes)),
            ]
        })
        .collect();
    format!(
        "== {title} ==\n{}",
        fmt::render_table(
            &["model", "optimizer", "params", "opt MiB", "ckpt MiB", "e2e MiB", "e2e GiB"],
            &body
        )
    )
}

/// The per-table model groupings from the paper.
pub fn table_models(table: &str) -> Result<Vec<&'static str>> {
    Ok(match table {
        "table1" => vec![
            "mobilenet_v2_cifar100",
            "resnet50_cifar100",
            "mobilenet_v2_imagenet",
            "resnet50_imagenet",
            "yolov5s",
            "yolov5m",
        ],
        "table2" => vec!["transformer_base", "transformer_big"],
        "table3" => vec!["bert_345m", "gpt2_345m", "t5_base"],
        "table4" => vec!["gpt2_124m", "t5_small", "llama7b_lora_r8"],
        "table6" => vec!["bert_base"],
        "table7" => vec!["llama7b_lora_r8"],
        "table8" => vec!["roberta_base", "albert_base_v2", "bert_base", "gpt2_124m"],
        "table9" => vec!["t5_small"],
        "table10" => vec!["t5_small", "marian_mt"],
        "table11" => vec!["t5_small"],
        "table12" => vec!["bart_base"],
        "table13" => vec!["mbart_large"],
        other => bail!("unknown memory table {other}"),
    })
}

// ---------------------------------------------------------------------------
// Table 5: optimization time per step
// ---------------------------------------------------------------------------

pub struct TimeRow {
    pub model: String,
    pub optimizer: String,
    pub mean_ms: f64,
    pub std_ms: f64,
}

/// Measure one optimizer step (the optimizer only — gradients are
/// precomputed random tensors) over a full model inventory, mirroring the
/// paper's Table 5 protocol of per-step optimization time. `threads`
/// selects the parallel step engine's worker count (1 = serial).
pub fn time_rows(models: &[&str], reps: usize, threads: usize) -> Result<Vec<TimeRow>> {
    let mut rows = Vec::new();
    for name in models {
        let inv = inventory_by_name(name).ok_or_else(|| anyhow!("unknown inventory {name}"))?;
        let shapes = inv.shapes();
        let mut rng = Pcg32::new(7);
        let mut params: Vec<Tensor> = shapes
            .iter()
            .map(|s| {
                let mut t = Tensor::zeros(s);
                rng.fill_normal(t.data_mut(), 0.05);
                t
            })
            .collect();
        let grads: Vec<Tensor> = shapes
            .iter()
            .map(|s| {
                let mut t = Tensor::zeros(s);
                rng.fill_normal(t.data_mut(), 0.01);
                t
            })
            .collect();
        for kind in OptKind::all() {
            let mut cfg = OptimConfig::paper_defaults(kind);
            cfg.threads = threads.max(1);
            let mut opt = optim::build(kind, &shapes, &cfg);
            // warmup
            opt.step(&mut params, &grads);
            let mut times = Vec::with_capacity(reps);
            for _ in 0..reps {
                let t0 = Instant::now();
                opt.step(&mut params, &grads);
                times.push(t0.elapsed().as_secs_f64() * 1e3);
            }
            let mean = times.iter().sum::<f64>() / times.len() as f64;
            let var =
                times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>() / times.len() as f64;
            rows.push(TimeRow {
                model: name.to_string(),
                optimizer: kind.name().into(),
                mean_ms: mean,
                std_ms: var.sqrt(),
            });
            println!("  [table5] {name} / {}: {mean:.1} ms", kind.name());
        }
    }
    Ok(rows)
}

pub fn render_time_table(rows: &[TimeRow]) -> String {
    // Annotate with the ratio to Adam on the same model (the paper's
    // headline claim is SMMF ≈ 1.2-1.6x Adam).
    let adam_ms = |model: &str| {
        rows.iter()
            .find(|r| r.model == model && r.optimizer == "adam")
            .map(|r| r.mean_ms)
            .unwrap_or(f64::NAN)
    };
    let body: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.model.clone(),
                r.optimizer.clone(),
                format!("{:.1} ± {:.1}", r.mean_ms, r.std_ms),
                format!("{:.2}x", r.mean_ms / adam_ms(&r.model)),
            ]
        })
        .collect();
    format!(
        "== Table 5: optimizer step time (optimizer only, full inventory) ==\n{}",
        fmt::render_table(&["model", "optimizer", "ms/step", "vs adam"], &body)
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn memory_rows_reproduce_table1_shape() {
        // The paper's Table 1 ordering on ResNet-50/ImageNet:
        // SMMF (3.7 MiB) << SM3 (99) < Adam (195) < Adafactor (220) < CAME (346).
        let rows = memory_rows(&["resnet50_imagenet"]).unwrap();
        let get = |o: &str| {
            rows.iter().find(|r| r.optimizer == o).map(|r| fmt::mib(r.opt_bytes)).unwrap()
        };
        let (smmf, sm3, adam, ada, came) =
            (get("smmf"), get("sm3"), get("adam"), get("adafactor"), get("came"));
        assert!(smmf < 5.0, "smmf={smmf}");
        assert!((90.0..110.0).contains(&sm3), "sm3={sm3}");
        assert!((185.0..205.0).contains(&adam), "adam={adam}");
        assert!((205.0..235.0).contains(&ada), "ada={ada}");
        assert!((330.0..360.0).contains(&came), "came={came}");
    }

    #[test]
    fn checkpoint_column_tracks_state_and_smmf_wins_on_disk() {
        let rows = memory_rows(&["transformer_base"]).unwrap();
        for r in &rows {
            // native serialization: disk = RAM + per-tensor framing only
            assert!(r.ckpt_bytes >= r.opt_bytes, "{}", r.optimizer);
            assert!(
                (r.ckpt_bytes - r.opt_bytes) as f64 <= 0.01 * r.opt_bytes as f64 + 65536.0,
                "{}: opt={} ckpt={}",
                r.optimizer,
                r.opt_bytes,
                r.ckpt_bytes
            );
        }
        let get = |o: &str| rows.iter().find(|r| r.optimizer == o).unwrap().ckpt_bytes;
        // Acceptance: SMMF's optimizer-state section ≤ 10% of Adam's.
        assert!(
            (get("smmf") as f64) <= 0.10 * get("adam") as f64,
            "smmf {} vs adam {}",
            get("smmf"),
            get("adam")
        );
    }

    #[test]
    fn table2_smmf_is_70x_smaller() {
        let rows = memory_rows(&["transformer_big"]).unwrap();
        let get = |o: &str| rows.iter().find(|r| r.optimizer == o).unwrap().opt_bytes;
        let ratio = get("adam") as f64 / get("smmf") as f64;
        assert!(ratio > 40.0, "ratio={ratio}");
    }

    #[test]
    fn all_tables_resolve() {
        for t in [
            "table1", "table2", "table3", "table4", "table6", "table7", "table8", "table9",
            "table10", "table11", "table12", "table13",
        ] {
            let models = table_models(t).unwrap();
            assert!(!models.is_empty());
            for m in models {
                assert!(inventory_by_name(m).is_some(), "{t}: {m}");
            }
        }
    }
}
