//! The `SMMFCELL` binary wire protocol: versioned, length-prefixed
//! framing for distributed suite-cell execution.
//!
//! Every message travels as one frame:
//!
//! ```text
//! offset  size  field
//! 0       8     magic  b"SMMFCELL"
//! 8       4     u32    protocol version (= 1)
//! 12      8     u64    request id (replies echo the request's id)
//! 20      1     u8     op code (see the OP_* constants)
//! 21      8     u64    payload length in bytes (<= MAX_PAYLOAD)
//! 29      len   op-specific payload
//! ```
//!
//! The framing deliberately mirrors `SMMFWIRE` (`server::protocol`),
//! byte for byte in layout, with its own magic, version and op space —
//! a worker fed a gradient frame (or a state server fed a cell frame)
//! rejects it at the magic check instead of misinterpreting it.
//!
//! All multi-byte values are little-endian, encoded/decoded with the
//! checkpoint blob codec (`optim::blob`). Decoding follows the same
//! strict discipline as `SMMFCKPT`/`SMMFWIRE` loading: magic/version/op
//! are validated before the payload is touched, the payload length is
//! capped before any allocation, every string length is checked against
//! its cap (and the bytes actually remaining) *before* the buffer is
//! built, and trailing payload bytes are rejected — a truncated or
//! hostile frame produces a context-rich error, never a panic or an
//! unbounded allocation. The byte-level spec lives in
//! `docs/SUITE_WIRE.md`.

use anyhow::{bail, Context, Result};
use std::io::{Read, Write};

use crate::optim::blob::{BlobReader, BlobWriter};

pub const MAGIC: &[u8; 8] = b"SMMFCELL";
pub const VERSION: u32 = 1;
/// Fixed frame-header size (see the module docs for the layout).
pub const HEADER_LEN: usize = 8 + 4 + 8 + 1 + 8;

/// Payload cap: a cell spec is a rendered TOML config plus short
/// strings, so 1 MiB is generous headroom — anything larger is a
/// corrupt or hostile frame.
pub const MAX_PAYLOAD: u64 = 1 << 20;
/// Cap for run/model/note/error strings.
pub const MAX_STR_LEN: usize = 4096;
/// Cap for the rendered per-cell config TOML.
pub const MAX_CONFIG_LEN: usize = 1 << 16;

// Requests (coordinator -> worker) occupy 1..; replies 64.. — disjoint
// ranges, like SMMFWIRE, so a peer answering with a request (or vice
// versa) is caught by `is_request` instead of decoding as nonsense.
pub const OP_SUBMIT: u8 = 1;
pub const OP_POLL: u8 = 2;
pub const OP_PING: u8 = 3;
pub const OP_SHUTDOWN: u8 = 4;

pub const OP_ACCEPTED: u8 = 64;
pub const OP_RUNNING: u8 = 65;
pub const OP_DONE: u8 = 66;
pub const OP_FAILED: u8 = 67;
pub const OP_BUSY: u8 = 68;
pub const OP_PONG: u8 = 69;
pub const OP_BYE: u8 = 70;
pub const OP_ERR: u8 = 71;

/// One `SMMFCELL` message (request or reply).
#[derive(Clone, Debug, PartialEq)]
pub enum CellMsg {
    /// Run a cell: `nonce` is the per-suite-run id the coordinator
    /// draws once per dispatch (so a persistent worker daemon never
    /// confuses two runs that reuse the same expansion indices), `job`
    /// the coordinator-chosen id (the cell's expansion index), `run`
    /// the cell directory name, `model` the workload spelling
    /// (`synthetic:…` or an artifact name), `config` the canonical TOML
    /// rendering of the resolved
    /// [`ExperimentConfig`](crate::coordinator::ExperimentConfig).
    /// Re-submitting a known `(nonce, job)` pair is idempotent: the
    /// worker answers with the job's current state instead of running
    /// it twice. The same `job` under a *different* nonce is fresh work
    /// — that is what keeps a `--force` re-run (or a second suite)
    /// against a long-lived worker from being answered with a stale
    /// verdict.
    Submit { nonce: u64, job: u64, run: String, model: String, config: String },
    /// Ask for a job's state; answered with `Running`, `Done`,
    /// `Failed`, or `Err` for an unknown `(nonce, job)`.
    Poll { nonce: u64, job: u64 },
    /// Heartbeat; answered with `Pong`.
    Ping,
    /// Stop accepting work and shut the worker down (answered with
    /// `Bye` first).
    Shutdown,

    /// Submit accepted; the cell is now running.
    Accepted { job: u64 },
    /// Poll reply: still training.
    Running { job: u64 },
    /// Poll reply: finished with a finite-loss `summary.json`.
    Done { job: u64 },
    /// Poll (or re-submit) reply: the cell failed; `note` is the first
    /// line of the error, mirrored in the cell's `FAILED` marker.
    Failed { job: u64, note: String },
    /// Submit bounced: the worker is at its concurrent-cell capacity.
    /// Back off and retry (or dispatch elsewhere).
    Busy,
    /// Heartbeat reply: current load.
    Pong { running: u32, capacity: u32 },
    /// Shutdown acknowledged.
    Bye,
    /// Protocol-level failure (malformed submit, unknown job, a reply
    /// op sent as a request, …).
    Err { msg: String },
}

impl CellMsg {
    pub fn op(&self) -> u8 {
        match self {
            CellMsg::Submit { .. } => OP_SUBMIT,
            CellMsg::Poll { .. } => OP_POLL,
            CellMsg::Ping => OP_PING,
            CellMsg::Shutdown => OP_SHUTDOWN,
            CellMsg::Accepted { .. } => OP_ACCEPTED,
            CellMsg::Running { .. } => OP_RUNNING,
            CellMsg::Done { .. } => OP_DONE,
            CellMsg::Failed { .. } => OP_FAILED,
            CellMsg::Busy => OP_BUSY,
            CellMsg::Pong { .. } => OP_PONG,
            CellMsg::Bye => OP_BYE,
            CellMsg::Err { .. } => OP_ERR,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            CellMsg::Submit { .. } => "Submit",
            CellMsg::Poll { .. } => "Poll",
            CellMsg::Ping => "Ping",
            CellMsg::Shutdown => "Shutdown",
            CellMsg::Accepted { .. } => "Accepted",
            CellMsg::Running { .. } => "Running",
            CellMsg::Done { .. } => "Done",
            CellMsg::Failed { .. } => "Failed",
            CellMsg::Busy => "Busy",
            CellMsg::Pong { .. } => "Pong",
            CellMsg::Bye => "Bye",
            CellMsg::Err { .. } => "Err",
        }
    }

    /// Is this a message a coordinator may send to a worker?
    pub fn is_request(&self) -> bool {
        self.op() < OP_ACCEPTED
    }
}

/// A framed message: request id + body. Replies echo the request's id.
#[derive(Clone, Debug, PartialEq)]
pub struct CellFrame {
    pub request_id: u64,
    pub msg: CellMsg,
}

/// Clip a string to [`MAX_STR_LEN`] bytes on a char boundary — applied
/// to outgoing notes/errors so an over-long anyhow chain can never
/// produce a frame the peer's decoder rejects.
pub fn clip_str(s: &str) -> &str {
    if s.len() <= MAX_STR_LEN {
        return s;
    }
    let mut end = MAX_STR_LEN;
    while !s.is_char_boundary(end) {
        end -= 1;
    }
    &s[..end]
}

fn write_str(w: &mut BlobWriter, s: &str) {
    w.u32(s.len() as u32);
    w.bytes(s.as_bytes());
}

/// Check a submit's strings against the decode-side caps, so an
/// oversized cell dies locally with a clear message instead of as the
/// peer's opaque protocol rejection. The encoder itself stays
/// infallible — callers (the dispatcher, [`CellClient::submit`]) run
/// this before framing.
///
/// [`CellClient::submit`]: crate::coordinator::remote::client::CellClient::submit
pub fn check_submit_limits(run: &str, model: &str, config: &str) -> Result<()> {
    for (what, len, cap) in [
        ("run", run.len(), MAX_STR_LEN),
        ("model", model.len(), MAX_STR_LEN),
        ("config", config.len(), MAX_CONFIG_LEN),
    ] {
        if len > cap {
            bail!("Submit.{what} is {len} bytes, over the wire cap ({cap})");
        }
    }
    Ok(())
}

fn payload(msg: &CellMsg) -> Vec<u8> {
    let mut w = BlobWriter::new();
    match msg {
        CellMsg::Submit { nonce, job, run, model, config } => {
            w.u64(*nonce);
            w.u64(*job);
            write_str(&mut w, run);
            write_str(&mut w, model);
            w.u32(config.len() as u32);
            w.bytes(config.as_bytes());
        }
        CellMsg::Poll { nonce, job } => {
            w.u64(*nonce);
            w.u64(*job);
        }
        CellMsg::Accepted { job } | CellMsg::Running { job } | CellMsg::Done { job } => {
            w.u64(*job)
        }
        CellMsg::Failed { job, note } => {
            w.u64(*job);
            write_str(&mut w, clip_str(note));
        }
        CellMsg::Pong { running, capacity } => {
            w.u32(*running);
            w.u32(*capacity);
        }
        CellMsg::Err { msg } => write_str(&mut w, clip_str(msg)),
        CellMsg::Ping | CellMsg::Shutdown | CellMsg::Busy | CellMsg::Bye => {}
    }
    w.finish()
}

/// Serialize a frame to bytes.
pub fn encode(frame: &CellFrame) -> Vec<u8> {
    let payload = payload(&frame.msg);
    assert!(
        payload.len() as u64 <= MAX_PAYLOAD,
        "frame payload {} exceeds MAX_PAYLOAD",
        payload.len()
    );
    let mut w = BlobWriter::new();
    w.bytes(MAGIC);
    w.u32(VERSION);
    w.u64(frame.request_id);
    w.u8(frame.msg.op());
    w.u64(payload.len() as u64);
    w.bytes(&payload);
    w.finish()
}

/// Write one frame to a stream (a single buffered `write_all`).
pub fn write_frame(w: &mut impl Write, frame: &CellFrame) -> std::io::Result<()> {
    w.write_all(&encode(frame))?;
    w.flush()
}

/// Parse and validate a frame header; returns `(request_id, op, payload
/// length)`. The length is already checked against [`MAX_PAYLOAD`].
pub fn decode_header(hdr: &[u8; HEADER_LEN]) -> Result<(u64, u8, u64)> {
    let mut r = BlobReader::new(hdr);
    let magic = r.bytes(8)?;
    if magic != MAGIC {
        bail!("not an SMMFCELL frame (bad magic {magic:02x?})");
    }
    let version = r.u32()?;
    if version != VERSION {
        bail!("unsupported SMMFCELL version {version} (supported: {VERSION})");
    }
    let request_id = r.u64()?;
    let op = r.u8()?;
    let len = r.u64()?;
    if len > MAX_PAYLOAD {
        bail!("frame op {op} claims a {len}-byte payload (cap {MAX_PAYLOAD})");
    }
    r.finish()?;
    Ok((request_id, op, len))
}

fn read_str(r: &mut BlobReader<'_>, what: &str) -> Result<String> {
    let len = r.u32()? as usize;
    if len > MAX_STR_LEN {
        bail!("{what}: string length {len} exceeds the cap ({MAX_STR_LEN})");
    }
    String::from_utf8(r.bytes(len)?.to_vec()).with_context(|| format!("{what}: not valid UTF-8"))
}

/// Decode an op-specific payload. The full payload must be consumed —
/// trailing bytes are rejected via `finish()`.
pub fn decode_payload(op: u8, body: &[u8]) -> Result<CellMsg> {
    let mut r = BlobReader::new(body);
    let msg = match op {
        OP_SUBMIT => {
            let nonce = r.u64()?;
            let job = r.u64()?;
            let run = read_str(&mut r, "Submit.run")?;
            let model = read_str(&mut r, "Submit.model")?;
            let len = r.u32()? as usize;
            if len > MAX_CONFIG_LEN {
                bail!("Submit.config: {len} bytes exceeds the cap ({MAX_CONFIG_LEN})");
            }
            // Length-vs-remaining check before the String allocation.
            if r.remaining() < len {
                bail!(
                    "Submit.config: claims {len} bytes, only {} payload bytes remain",
                    r.remaining()
                );
            }
            let config = String::from_utf8(r.bytes(len)?.to_vec())
                .context("Submit.config: not valid UTF-8")?;
            CellMsg::Submit { nonce, job, run, model, config }
        }
        OP_POLL => CellMsg::Poll { nonce: r.u64()?, job: r.u64()? },
        OP_PING => CellMsg::Ping,
        OP_SHUTDOWN => CellMsg::Shutdown,
        OP_ACCEPTED => CellMsg::Accepted { job: r.u64()? },
        OP_RUNNING => CellMsg::Running { job: r.u64()? },
        OP_DONE => CellMsg::Done { job: r.u64()? },
        OP_FAILED => {
            let job = r.u64()?;
            let note = read_str(&mut r, "Failed.note")?;
            CellMsg::Failed { job, note }
        }
        OP_BUSY => CellMsg::Busy,
        OP_PONG => CellMsg::Pong { running: r.u32()?, capacity: r.u32()? },
        OP_BYE => CellMsg::Bye,
        OP_ERR => CellMsg::Err { msg: read_str(&mut r, "Err.msg")? },
        other => bail!("unknown SMMFCELL op {other}"),
    };
    r.finish().with_context(|| format!("decoding op {op} ({})", msg.name()))?;
    Ok(msg)
}

/// Decode one complete frame from a byte slice (tests / in-memory use).
/// The slice must hold exactly one frame.
pub fn decode(buf: &[u8]) -> Result<CellFrame> {
    if buf.len() < HEADER_LEN {
        bail!("truncated frame: {} bytes, header alone needs {HEADER_LEN}", buf.len());
    }
    let hdr: [u8; HEADER_LEN] = buf[..HEADER_LEN].try_into().unwrap();
    let (request_id, op, len) = decode_header(&hdr)?;
    let body = &buf[HEADER_LEN..];
    if (body.len() as u64) < len {
        bail!("truncated frame: payload claims {len} bytes, {} present", body.len());
    }
    if (body.len() as u64) > len {
        bail!("frame has {} trailing bytes", body.len() as u64 - len);
    }
    let msg = decode_payload(op, body)?;
    Ok(CellFrame { request_id, msg })
}

/// Read one frame from a stream: header first (validated before the
/// payload is buffered), then exactly `len` payload bytes.
pub fn read_frame(r: &mut impl Read) -> Result<CellFrame> {
    let mut hdr = [0u8; HEADER_LEN];
    r.read_exact(&mut hdr).context("reading SMMFCELL frame header")?;
    let (request_id, op, len) = decode_header(&hdr)?;
    let mut body = vec![0u8; len as usize];
    r.read_exact(&mut body)
        .with_context(|| format!("reading {len}-byte payload of op {op}"))?;
    let msg = decode_payload(op, &body)?;
    Ok(CellFrame { request_id, msg })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn all_msgs() -> Vec<CellMsg> {
        vec![
            CellMsg::Submit {
                nonce: 0xFEED_BEEF,
                job: 3,
                run: "quad-adam-s0".into(),
                model: "synthetic:tiny_lm".into(),
                config: "name = \"x\"\n[train]\nsteps = 4\n".into(),
            },
            CellMsg::Poll { nonce: 0xFEED_BEEF, job: 9 },
            CellMsg::Ping,
            CellMsg::Shutdown,
            CellMsg::Accepted { job: 3 },
            CellMsg::Running { job: 3 },
            CellMsg::Done { job: 3 },
            CellMsg::Failed { job: 3, note: "diverged: non-finite loss".into() },
            CellMsg::Busy,
            CellMsg::Pong { running: 1, capacity: 2 },
            CellMsg::Bye,
            CellMsg::Err { msg: "unknown job 77".into() },
        ]
    }

    #[test]
    fn every_message_round_trips() {
        for (i, msg) in all_msgs().into_iter().enumerate() {
            let f = CellFrame { request_id: 100 + i as u64, msg };
            let bytes = encode(&f);
            assert_eq!(&bytes[..8], MAGIC);
            assert_eq!(decode(&bytes).unwrap(), f, "frame {i}");
        }
    }

    #[test]
    fn request_reply_ranges_are_disjoint() {
        for msg in all_msgs() {
            let is_req = matches!(
                msg,
                CellMsg::Submit { .. } | CellMsg::Poll { .. } | CellMsg::Ping | CellMsg::Shutdown
            );
            assert_eq!(msg.is_request(), is_req, "{}", msg.name());
            if is_req {
                assert!(msg.op() < OP_ACCEPTED);
            } else {
                assert!(msg.op() >= OP_ACCEPTED);
            }
        }
    }

    #[test]
    fn header_rejects_bad_magic_version_and_oversized_claims() {
        let good = encode(&CellFrame { request_id: 1, msg: CellMsg::Ping });
        let mut bad = good.clone();
        bad[0] = b'X';
        assert!(decode(&bad).unwrap_err().to_string().contains("bad magic"));
        let mut bad = good.clone();
        bad[8] = 0xEE; // version
        assert!(decode(&bad).unwrap_err().to_string().contains("version"));
        let mut bad = good.clone();
        bad[21..29].copy_from_slice(&u64::MAX.to_le_bytes()); // payload len
        assert!(decode(&bad).unwrap_err().to_string().contains("cap"));
    }

    #[test]
    fn trailing_and_truncated_payloads_are_rejected() {
        let mut bytes =
            encode(&CellFrame { request_id: 7, msg: CellMsg::Poll { nonce: 2, job: 1 } });
        bytes.push(0); // trailing byte after the framed payload
        assert!(decode(&bytes).unwrap_err().to_string().contains("trailing"));
        let bytes = encode(&CellFrame { request_id: 7, msg: CellMsg::Poll { nonce: 2, job: 1 } });
        assert!(decode(&bytes[..bytes.len() - 1]).unwrap_err().to_string().contains("truncated"));
        // in-payload trailing bytes (op says Ping, payload is non-empty)
        assert!(decode_payload(OP_PING, &[0u8]).is_err());
    }

    #[test]
    fn string_caps_are_checked_before_allocation() {
        // A Submit whose config length field claims far more bytes than
        // the payload holds must be rejected by the remaining-bytes
        // check, not by an allocation attempt.
        let mut w = crate::optim::blob::BlobWriter::new();
        w.u64(7); // nonce
        w.u64(1); // job
        w.u32(1);
        w.bytes(b"r");
        w.u32(1);
        w.bytes(b"m");
        w.u32(60_000); // config "length" with no bytes behind it
        let body = w.finish();
        let err = decode_payload(OP_SUBMIT, &body).unwrap_err().to_string();
        assert!(err.contains("remain"), "{err}");
        // and an over-cap claim is rejected even earlier
        let mut w = crate::optim::blob::BlobWriter::new();
        w.u64(7); // nonce
        w.u64(1); // job
        w.u32(1);
        w.bytes(b"r");
        w.u32(1);
        w.bytes(b"m");
        w.u32((MAX_CONFIG_LEN + 1) as u32);
        let body = w.finish();
        let err = decode_payload(OP_SUBMIT, &body).unwrap_err().to_string();
        assert!(err.contains("cap"), "{err}");
        // over-long outgoing notes are clipped on a char boundary
        let long = "é".repeat(MAX_STR_LEN);
        let clipped = clip_str(&long);
        assert!(clipped.len() <= MAX_STR_LEN);
        assert!(long.starts_with(clipped));
    }

    #[test]
    fn submit_limits_are_checked_before_framing() {
        assert!(check_submit_limits("run", "model", "steps = 1\n").is_ok());
        // right at each cap is fine
        let max_s = "x".repeat(MAX_STR_LEN);
        let max_c = "x".repeat(MAX_CONFIG_LEN);
        assert!(check_submit_limits(&max_s, &max_s, &max_c).is_ok());
        // one byte over any cap fails locally with the field named
        let over_s = "x".repeat(MAX_STR_LEN + 1);
        let over_c = "x".repeat(MAX_CONFIG_LEN + 1);
        for (run, model, config, field) in [
            (over_s.as_str(), "m", "c", "Submit.run"),
            ("r", over_s.as_str(), "c", "Submit.model"),
            ("r", "m", over_c.as_str(), "Submit.config"),
        ] {
            let err = check_submit_limits(run, model, config).unwrap_err().to_string();
            assert!(err.contains(field) && err.contains("cap"), "{err}");
        }
    }

    #[test]
    fn stream_roundtrip_back_to_back() {
        let frames: Vec<CellFrame> = all_msgs()
            .into_iter()
            .enumerate()
            .map(|(i, msg)| CellFrame { request_id: i as u64, msg })
            .collect();
        let mut buf = Vec::new();
        for f in &frames {
            write_frame(&mut buf, f).unwrap();
        }
        let mut r = &buf[..];
        for f in &frames {
            assert_eq!(&read_frame(&mut r).unwrap(), f);
        }
        assert!(r.is_empty());
    }
}
