//! Thin synchronous client for one `repro worker` connection.
//!
//! Deliberately dumber than [`server::Client`](crate::server::client):
//! no internal `Busy` absorption, no retry loop — the
//! [`dispatch`](super::dispatch) scheduler owns retry/backoff policy
//! because a `Busy` bounce is a *scheduling* signal there (defer this
//! worker, maybe try another), not something to hide inside a blocking
//! call. What the client does own is framing hygiene: requests carry a
//! monotonically increasing id and every reply must echo it.

use anyhow::{anyhow, bail, Context, Result};
use std::io::{BufReader, BufWriter};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

use crate::coordinator::remote::protocol::{self, CellFrame, CellMsg};

/// One connection to a worker daemon.
pub struct CellClient {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    next_id: u64,
}

impl CellClient {
    /// Connect with a dial timeout; `io_timeout` bounds every
    /// subsequent read/write (`None` = block forever). `addr` may be an
    /// IP literal or a DNS hostname (`HOST:PORT` — the grammar
    /// `remote:HOST:PORT` advertises); every resolved address is tried
    /// in order.
    pub fn connect(addr: &str, io_timeout: Option<Duration>) -> Result<CellClient> {
        let sock_addrs: Vec<_> = addr
            .to_socket_addrs()
            .with_context(|| format!("bad worker address {addr:?} (expected HOST:PORT)"))?
            .collect();
        let dial = io_timeout.unwrap_or(Duration::from_secs(5));
        let mut last_err = None;
        let stream = sock_addrs
            .iter()
            .find_map(|sa| match TcpStream::connect_timeout(sa, dial) {
                Ok(s) => Some(s),
                Err(e) => {
                    last_err = Some(e);
                    None
                }
            })
            .ok_or_else(|| match last_err {
                Some(e) => anyhow!(e),
                None => anyhow!("{addr:?} resolved to no addresses"),
            })
            .with_context(|| format!("connecting to worker {addr}"))?;
        stream.set_nodelay(true).ok();
        stream.set_read_timeout(io_timeout)?;
        stream.set_write_timeout(io_timeout)?;
        let read_half = stream.try_clone()?;
        Ok(CellClient {
            reader: BufReader::new(read_half),
            writer: BufWriter::new(stream),
            next_id: 1,
        })
    }

    /// One request → reply round trip, with the id echo checked.
    pub fn call(&mut self, msg: CellMsg) -> Result<CellMsg> {
        debug_assert!(msg.is_request(), "{} is not a request", msg.name());
        let id = self.next_id;
        self.next_id += 1;
        protocol::write_frame(&mut self.writer, &CellFrame { request_id: id, msg })
            .context("writing to worker")?;
        let reply = protocol::read_frame(&mut self.reader).context("reading worker reply")?;
        if reply.request_id != id {
            bail!("worker answered request {} while {id} was pending", reply.request_id);
        }
        Ok(reply.msg)
    }

    /// Submit cell `job` under suite-run `nonce` (`run`/`model`/
    /// canonical config TOML). Strings over the wire caps fail here,
    /// locally and by name, instead of as the worker's opaque decode
    /// rejection.
    pub fn submit(
        &mut self,
        nonce: u64,
        job: u64,
        run: &str,
        model: &str,
        config: &str,
    ) -> Result<CellMsg> {
        protocol::check_submit_limits(run, model, config)?;
        self.call(CellMsg::Submit {
            nonce,
            job,
            run: run.to_string(),
            model: model.to_string(),
            config: config.to_string(),
        })
    }

    /// Ask for `job`'s state under suite-run `nonce`.
    pub fn poll(&mut self, nonce: u64, job: u64) -> Result<CellMsg> {
        self.call(CellMsg::Poll { nonce, job })
    }

    /// Heartbeat; returns `(running, capacity)`.
    pub fn ping(&mut self) -> Result<(u32, u32)> {
        match self.call(CellMsg::Ping)? {
            CellMsg::Pong { running, capacity } => Ok((running, capacity)),
            other => bail!("expected Pong, worker answered {}", other.name()),
        }
    }

    /// Ask the worker to shut down (acknowledged with `Bye`).
    pub fn shutdown(&mut self) -> Result<()> {
        match self.call(CellMsg::Shutdown)? {
            CellMsg::Bye => Ok(()),
            other => bail!("expected Bye, worker answered {}", other.name()),
        }
    }
}
