//! Distributed suite execution: ship expanded suite cells to `repro
//! worker` daemons over the `SMMFCELL` wire protocol and collect
//! verdicts in deterministic expansion order.
//!
//! Module map:
//!
//! * [`protocol`] — the `SMMFCELL` framing and message codec
//!   (`SMMFWIRE`-style strict decode; byte spec in
//!   `docs/SUITE_WIRE.md`).
//! * [`service`] — the worker daemon behind `repro worker`: accept
//!   loop, per-connection handlers, per-cell executor threads.
//! * [`client`] — one typed connection to a worker (submit / poll /
//!   ping / shutdown).
//! * [`dispatch`] — the coordinator-side scheduler that replaces the
//!   local thread pool when `[suite] workers` names remote addresses:
//!   per-worker in-flight caps, `Busy` backoff, lease-based death
//!   detection with re-dispatch, and the slot-per-cell status table
//!   that keeps reports byte-identical to a local run.
//!
//! The subsystem adds *no* new execution semantics: a remote cell runs
//! through the same [`suite::execute_cell`](crate::coordinator::suite)
//! path, leaves the same artifacts, and is cached by the same
//! `summary.json`/`FAILED` re-entry rules as a local one.

pub mod client;
pub mod dispatch;
pub mod protocol;
pub mod service;

pub use client::CellClient;
pub use dispatch::run_dispatched;
pub use service::{WorkerOptions, WorkerServer, WorkerStats};
