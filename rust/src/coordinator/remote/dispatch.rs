//! The remote suite backend: a submit/poll dispatcher that fans
//! expanded cells out to `repro worker` daemons (plus optional local
//! lanes) and commits statuses in deterministic expansion order.
//!
//! Scheduling model:
//!
//! * One **work queue** holds the indices of every cell that still
//!   needs to run, in expansion order; remote lanes and local lanes pop
//!   from the same queue, so `local:N,remote:…` mixes trivially.
//! * Each remote **lane** keeps at most [`INFLIGHT_PER_WORKER`] cells
//!   in flight: submit → poll until `Done`/`Failed`. `Busy` bounces
//!   requeue the cell and defer that lane through the shared
//!   [`Backoff`] (the same deterministic-jitter schedule
//!   `server::Client` retries with).
//! * **Leases**: every successful round trip refreshes a lane's
//!   `last_ok` — and a lane with no submit/poll traffic (idle, or
//!   deferred on `Busy`) is pinged once its lease is half spent, so a
//!   healthy-but-idle worker is never mistaken for a dead one. A lane
//!   silent past the lease timeout is declared dead and its in-flight
//!   cells are requeued to the survivors — after a re-entry-cache
//!   recheck, because a stranded worker may have finished a cell before
//!   dying (its `summary.json` is the verdict, not its lost reply).
//!   With every remote lane dead and no local lanes, the remainder
//!   fails loudly with `FAILED` markers instead of hanging: the next
//!   invocation retries exactly those cells.
//! * **Determinism**: statuses land in a slot-per-cell table keyed by
//!   expansion index; which worker finished first is invisible to the
//!   caller, so [`report`](crate::coordinator::report) renders
//!   byte-identical `docs/RESULTS.md` / `BENCH_suite.json` regardless
//!   of backend or completion timing.
//!
//! The wire config is [`ExperimentConfig::to_toml`]'s canonical
//! rendering; before a cell ships, the dispatcher re-parses that text
//! and verifies it reproduces the resolved config exactly — a
//! non-round-tripping config is a per-cell failure, never a silently
//! drifted remote run.
//!
//! [`ExperimentConfig::to_toml`]: crate::coordinator::config::ExperimentConfig::to_toml

use anyhow::Result;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::coordinator::config::{ExperimentConfig, SuiteCell, WorkerSpec};
use crate::coordinator::remote::client::CellClient;
use crate::coordinator::remote::protocol::{self, CellMsg};
use crate::coordinator::suite::{self, CellStatus, SuiteOptions};
use crate::coordinator::workers::panic_note;
use crate::train::metrics;
use crate::util::backoff::Backoff;

/// Cells a single worker daemon may have in flight at once. Two keeps a
/// capacity-1 worker saturated (one running, one queued behind its
/// `Busy` bounces) without piling risk onto one lease.
pub const INFLIGHT_PER_WORKER: usize = 2;

/// One lane state transition: bump its `remote.*` counter in the global
/// observability registry and drop a `lane.*` marker on the trace.
/// Lane events are per-submit/per-verdict, not per-step, so the
/// registry name lookup is cheap enough to take inline.
fn lane_event(counter: &'static str, mark: &'static str) {
    crate::obs::metrics::global().counter(counter).fetch_add(1, Ordering::Relaxed);
    crate::obs::trace::mark("suite", mark);
}

/// One remote worker's dispatch lane.
struct Lane {
    addr: String,
    client: Option<CellClient>,
    /// Expansion indices of cells submitted here and not yet resolved.
    inflight: Vec<usize>,
    dead: bool,
    /// Busy-bounce deferral: no submits to this lane before this
    /// instant (polls continue — deferral is backpressure, not death).
    defer_until: Option<Instant>,
    busy_backoff: Backoff,
    /// Last successful round trip; the lease clock.
    last_ok: Instant,
    /// The first dial failure is logged (once per lane) so an
    /// unresolvable hostname or refused port is diagnosable instead of
    /// surfacing only as a lease-expiry message.
    dial_err_logged: bool,
}

impl Lane {
    /// Take the connection (dialing if needed) so calls can run while
    /// the lane's bookkeeping fields stay mutable. Put it back with
    /// `self.client = Some(c)` after a healthy exchange; drop it on an
    /// IO error and the next take re-dials.
    fn take_client(&mut self, io: Duration) -> Option<CellClient> {
        match self.client.take() {
            Some(c) => Some(c),
            // Dial failure: leave `client` empty; the lease clock keeps
            // ticking toward this lane's death.
            None => match CellClient::connect(&self.addr, Some(io)) {
                Ok(c) => Some(c),
                Err(e) => {
                    if !self.dial_err_logged {
                        self.dial_err_logged = true;
                        println!("[suite] worker {}: dial failed: {e:#}", self.addr);
                    }
                    None
                }
            },
        }
    }
}

/// Shared scheduling state: the work queue plus the slot-per-cell
/// status table that makes completion order invisible to the caller.
struct Board<'a> {
    cells: &'a [SuiteCell],
    total: usize,
    pending: Mutex<VecDeque<usize>>,
    statuses: Mutex<Vec<Option<CellStatus>>>,
    /// Set by the dispatcher once nothing is pending or in flight —
    /// releases the local lanes, which otherwise idle awaiting requeues.
    done: AtomicBool,
}

impl<'a> Board<'a> {
    fn record(&self, idx: usize, status: CellStatus) {
        self.statuses.lock().unwrap()[idx] = Some(status);
    }

    fn requeue_front(&self, idx: usize) {
        lane_event("remote.requeues_total", "lane.requeue");
        self.pending.lock().unwrap().push_front(idx);
    }

    fn pop(&self) -> Option<usize> {
        self.pending.lock().unwrap().pop_front()
    }

    /// The re-dispatch cache recheck: a popped cell whose summary
    /// already landed (a stranded worker finished it before dying, or a
    /// lost reply hid a completion) counts as `Ran` — the on-disk
    /// verdict outranks the lost acknowledgment. Returns `None` when
    /// the cell is already settled.
    fn claim(&self, idx: usize) -> Option<usize> {
        let cell = &self.cells[idx];
        if suite::cell_cached(cell, false) {
            println!(
                "{}: completed remotely (summary.json present)",
                suite::cell_tag(idx, self.total, &cell.run)
            );
            self.record(idx, CellStatus::Ran);
            return None;
        }
        Some(idx)
    }

    fn fail(&self, idx: usize, note: String) {
        let cell = &self.cells[idx];
        let status = suite::fail_cell(
            &suite::cell_tag(idx, self.total, &cell.run),
            &suite::cell_dir(cell),
            note,
        );
        self.record(idx, status);
    }
}

/// Run a suite's cells over the remote (or mixed) backend described by
/// `spec`. Statuses come back in expansion order; per-cell failures are
/// isolated into [`CellStatus::Failed`] exactly like the local pool.
pub fn run_dispatched(
    cells: &[SuiteCell],
    spec: &WorkerSpec,
    opts: &SuiteOptions,
) -> Result<Vec<CellStatus>> {
    let total = cells.len();
    let lease = Duration::from_millis(opts.lease_timeout_ms.max(1));
    // IO timeout well under the lease: a silent worker must miss
    // several round trips before its lease expires, not exactly one.
    let io_timeout = Duration::from_millis((opts.lease_timeout_ms / 2).max(50));

    let board = Board {
        cells,
        total,
        pending: Mutex::new(VecDeque::new()),
        statuses: Mutex::new(vec![None; total]),
        done: AtomicBool::new(false),
    };

    // Pre-pass in expansion order: the re-entry cache decides what runs
    // at all — identical to the local backend's cached check.
    for (idx, cell) in cells.iter().enumerate() {
        if suite::cell_cached(cell, opts.force) {
            println!(
                "{}: cached (summary.json exists — use --force to re-run)",
                suite::cell_tag(idx, total, &cell.run)
            );
            board.record(idx, CellStatus::Skipped);
            continue;
        }
        if opts.force {
            let _ = std::fs::remove_file(metrics::summary_path(&cell.cfg.out_dir, &cell.cfg.name));
        }
        board.pending.lock().unwrap().push_back(idx);
    }

    std::thread::scope(|scope| {
        // Local lanes: same executor as the in-process pool, but fed
        // from the shared queue so they absorb re-dispatched cells too.
        for _ in 0..spec.local {
            scope.spawn(|| loop {
                let Some(idx) = board.pop() else {
                    if board.done.load(Ordering::SeqCst) {
                        return;
                    }
                    std::thread::sleep(Duration::from_millis(5));
                    continue;
                };
                let Some(idx) = board.claim(idx) else { continue };
                let cell = &cells[idx];
                let tag = suite::cell_tag(idx, total, &cell.run);
                let status = match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    suite::execute_cell(&tag, cell, &opts.artifacts_dir)
                })) {
                    Ok(s) => s,
                    Err(payload) => suite::fail_cell(
                        &tag,
                        &suite::cell_dir(cell),
                        format!("cell worker panicked: {}", panic_note(payload.as_ref())),
                    ),
                };
                board.record(idx, status);
            });
        }
        // One dispatcher thread drives every remote lane — the per-call
        // IO timeouts bound each round trip, so a stuck worker stalls
        // only its own lane's turn, never the loop.
        scope.spawn(|| dispatch_loop(&board, spec, lease, io_timeout));
    });

    let statuses = board.statuses.into_inner().unwrap();
    Ok(statuses
        .into_iter()
        .enumerate()
        .map(|(idx, s)| {
            // Defensive: every path above records a status; a hole
            // would silently corrupt the report's expansion order.
            s.unwrap_or_else(|| {
                suite::fail_cell(
                    &suite::cell_tag(idx, total, &cells[idx].run),
                    &suite::cell_dir(&cells[idx]),
                    "cell was never scheduled (dispatcher bug)".into(),
                )
            })
        })
        .collect())
}

/// Draw the per-suite-run nonce that scopes job ids on the workers:
/// OS-seeded hasher state mixed with the wall clock, so two dispatches
/// — even from the same process — never share one.
fn suite_nonce() -> u64 {
    use std::hash::{BuildHasher, Hasher};
    let seed = std::collections::hash_map::RandomState::new().build_hasher().finish();
    let clock = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0);
    seed ^ clock.rotate_left(17)
}

fn dispatch_loop(board: &Board<'_>, spec: &WorkerSpec, lease: Duration, io: Duration) {
    let nonce = suite_nonce();
    let mut lanes: Vec<Lane> = spec
        .remote
        .iter()
        .map(|addr| Lane {
            addr: addr.clone(),
            client: None,
            inflight: Vec::new(),
            dead: false,
            defer_until: None,
            busy_backoff: Backoff::new(),
            last_ok: Instant::now(),
            dial_err_logged: false,
        })
        .collect();
    let mut pacing = Backoff::new();
    loop {
        let mut progress = false;
        for lane in &mut lanes {
            if lane.dead {
                continue;
            }
            progress |= poll_lane(board, lane, nonce, io);
            progress |= fill_lane(board, lane, nonce, io);
            heartbeat_lane(lane, lease, io);
            if lane.last_ok.elapsed() > lease {
                lane_event("remote.lane_deaths_total", "lane.dead");
                lane.dead = true;
                lane.client = None;
                let stranded = std::mem::take(&mut lane.inflight);
                println!(
                    "[suite] worker {} unreachable (lease {} ms expired) — re-dispatching \
                     {} cell(s)",
                    lane.addr,
                    lease.as_millis(),
                    stranded.len()
                );
                // Front of the queue: the survivors should pick these up
                // before fresh work, keeping completion close to
                // expansion order.
                for idx in stranded.into_iter().rev() {
                    board.requeue_front(idx);
                }
                progress = true;
            }
        }
        let inflight_total: usize = lanes.iter().map(|l| l.inflight.len()).sum();
        let pending_len = board.pending.lock().unwrap().len();
        if pending_len == 0 && inflight_total == 0 {
            board.done.store(true, Ordering::SeqCst);
            return;
        }
        if inflight_total == 0 && spec.local == 0 && lanes.iter().all(|l| l.dead) {
            // Nothing can make progress: fail the remainder loudly. The
            // FAILED markers make the next invocation retry exactly
            // these cells.
            while let Some(idx) = board.pop() {
                let Some(idx) = board.claim(idx) else { continue };
                board.fail(idx, "no live workers (every remote worker's lease expired)".into());
            }
            board.done.store(true, Ordering::SeqCst);
            return;
        }
        if progress {
            pacing.reset();
        } else {
            // Deterministic-jitter idle pacing, capped at 50 ms — the
            // same schedule the state-server client retries with.
            pacing.sleep();
        }
    }
}

/// Keep a quiet lane's lease honest: submit/poll traffic refreshes
/// `last_ok` as a side effect, but an idle lane (nothing in flight,
/// nothing to submit) or a `Busy`-deferred one makes no round trips at
/// all — without a heartbeat it would be declared dead the moment its
/// lease ran out, despite being perfectly healthy. Once the lease is
/// half spent with no traffic, ping; success refreshes the lease, while
/// a genuinely unreachable worker keeps ticking toward expiry.
fn heartbeat_lane(lane: &mut Lane, lease: Duration, io: Duration) {
    if lane.last_ok.elapsed() <= lease / 2 {
        return;
    }
    let Some(mut client) = lane.take_client(io) else { return };
    if client.ping().is_ok() {
        lane_event("remote.heartbeats_total", "lane.heartbeat");
        lane.last_ok = Instant::now();
        lane.client = Some(client);
    }
    // Ping failure drops the connection; the next take re-dials.
}

/// Poll a lane's in-flight cells once each. Returns whether any cell
/// reached a verdict.
fn poll_lane(board: &Board<'_>, lane: &mut Lane, nonce: u64, io: Duration) -> bool {
    if lane.inflight.is_empty() {
        return false;
    }
    let Some(mut client) = lane.take_client(io) else { return false };
    let mut progress = false;
    let mut i = 0;
    while i < lane.inflight.len() {
        let idx = lane.inflight[i];
        let reply = match client.poll(nonce, idx as u64) {
            Ok(r) => r,
            // Lost round trip: keep the cell in flight (the worker may
            // just be slow), drop the connection — the lease clock
            // decides death, and the next take re-dials.
            Err(_) => return progress,
        };
        lane.last_ok = Instant::now();
        match reply {
            CellMsg::Running { .. } => i += 1,
            CellMsg::Done { .. } => {
                let removed = lane.inflight.remove(i);
                done_on(board, removed, &lane.addr);
                progress = true;
            }
            CellMsg::Failed { note, .. } => {
                let removed = lane.inflight.remove(i);
                board.fail(removed, note);
                progress = true;
            }
            // Unknown job (worker restarted?) or a nonsense reply:
            // this lane no longer owns the cell.
            _ => {
                let removed = lane.inflight.remove(i);
                board.requeue_front(removed);
                progress = true;
            }
        }
    }
    lane.client = Some(client);
    progress
}

/// Top a lane up to [`INFLIGHT_PER_WORKER`] from the queue. Returns
/// whether anything was submitted or resolved.
fn fill_lane(board: &Board<'_>, lane: &mut Lane, nonce: u64, io: Duration) -> bool {
    if let Some(until) = lane.defer_until {
        if Instant::now() < until {
            return false;
        }
        lane.defer_until = None;
    }
    if lane.inflight.len() >= INFLIGHT_PER_WORKER {
        return false;
    }
    let mut progress = false;
    let mut client: Option<CellClient> = None;
    while lane.inflight.len() < INFLIGHT_PER_WORKER {
        let Some(idx) = board.pop() else { break };
        let Some(idx) = board.claim(idx) else {
            progress = true;
            continue;
        };
        let cell = &board.cells[idx];
        let tag = suite::cell_tag(idx, board.total, &cell.run);
        // Canonical wire rendering; a config the wire cannot carry is a
        // per-cell failure, not a suite abort.
        let config = match cell.cfg.to_toml() {
            Ok(c) => c,
            Err(e) => {
                board.fail(idx, format!("cannot ship cell to a remote worker: {e:#}"));
                progress = true;
                continue;
            }
        };
        // Ship-time round-trip guard: the worker rebuilds the cell from
        // this text alone, so it must reproduce the resolved config
        // exactly — a drift here would train a silently different cell.
        match ExperimentConfig::from_toml_str(&config) {
            Ok(back) if back == cell.cfg => {}
            Ok(_) => {
                board.fail(
                    idx,
                    "cannot ship cell to a remote worker: config does not survive the \
                     wire TOML round trip"
                        .into(),
                );
                progress = true;
                continue;
            }
            Err(e) => {
                board.fail(
                    idx,
                    format!("cannot ship cell to a remote worker: wire config fails to \
                             re-parse: {e:#}"),
                );
                progress = true;
                continue;
            }
        }
        // And the decode-side size caps: an over-long run/model/config
        // fails here, by name, not as the peer's opaque rejection.
        if let Err(e) = protocol::check_submit_limits(&cell.run, &cell.model, &config) {
            board.fail(idx, format!("cannot ship cell to a remote worker: {e:#}"));
            progress = true;
            continue;
        }
        if client.is_none() {
            client = lane.take_client(io);
        }
        let Some(c) = client.as_mut() else {
            board.requeue_front(idx);
            break;
        };
        let reply = match c.submit(nonce, idx as u64, &cell.run, &cell.model, &config) {
            Ok(r) => r,
            Err(_) => {
                board.requeue_front(idx);
                client = None; // re-dial next round
                break;
            }
        };
        lane.last_ok = Instant::now();
        match reply {
            CellMsg::Accepted { .. } | CellMsg::Running { .. } => {
                lane_event("remote.submits_total", "lane.submit");
                println!("{tag}: dispatched to worker {}", lane.addr);
                lane.inflight.push(idx);
                progress = true;
            }
            // Idempotent re-submit of an already-finished job.
            CellMsg::Done { .. } => {
                done_on(board, idx, &lane.addr);
                progress = true;
            }
            CellMsg::Failed { note, .. } => {
                board.fail(idx, note);
                progress = true;
            }
            CellMsg::Busy => {
                lane_event("remote.busy_retries_total", "lane.busy");
                board.requeue_front(idx);
                lane.defer_until = Some(Instant::now() + lane.busy_backoff.next_delay());
                break;
            }
            CellMsg::Err { msg } => {
                // The worker rejected the cell itself (bad config,
                // hostile path): a cell verdict, not a lane fault.
                board.fail(idx, format!("worker {} rejected the cell: {msg}", lane.addr));
                progress = true;
            }
            _ => {
                board.requeue_front(idx);
                client = None;
                break;
            }
        }
    }
    if let Some(c) = client {
        lane.client = Some(c);
    }
    if progress {
        lane.busy_backoff.reset();
    }
    progress
}

/// Commit a remote completion. (Failures flow through [`Board::fail`],
/// which also mirrors the note into the coordinator-side `FAILED`
/// marker — the worker already wrote one, but a shared filesystem is
/// not part of the protocol and the write is idempotent.)
fn done_on(board: &Board<'_>, idx: usize, addr: &str) {
    lane_event("remote.done_total", "lane.done");
    let cell = &board.cells[idx];
    println!("{}: done on worker {addr}", suite::cell_tag(idx, board.total, &cell.run));
    board.record(idx, CellStatus::Ran);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::remote::service::{WorkerOptions, WorkerServer};

    fn lane(addr: String) -> Lane {
        Lane {
            addr,
            client: None,
            inflight: Vec::new(),
            dead: false,
            defer_until: None,
            busy_backoff: Backoff::new(),
            last_ok: Instant::now(),
            dial_err_logged: false,
        }
    }

    /// The idle-lane half of the lease story: submit/poll traffic is
    /// what normally refreshes `last_ok`, so a lane with nothing in
    /// flight and nothing to submit would otherwise be declared dead at
    /// lease expiry despite a perfectly healthy worker.
    #[test]
    fn heartbeat_pings_refresh_an_idle_lane_against_a_live_worker() {
        let server = WorkerServer::start(&WorkerOptions::default()).unwrap();
        let mut l = lane(server.addr.to_string());
        let lease = Duration::from_millis(10_000);
        // Lease not yet half spent: no ping, no connection dialed.
        heartbeat_lane(&mut l, lease, Duration::from_secs(5));
        assert!(l.client.is_none(), "no ping before the lease is half spent");
        // Back-date the clock past the half-lease mark: the heartbeat
        // must ping and pull `last_ok` back under the expiry threshold.
        l.last_ok = Instant::now() - Duration::from_millis(6_000);
        heartbeat_lane(&mut l, lease, Duration::from_secs(5));
        assert!(
            l.last_ok.elapsed() < Duration::from_millis(5_000),
            "successful ping refreshed the lease clock"
        );
        assert!(l.client.is_some(), "healthy connection is kept for reuse");
        server.stop();
    }

    #[test]
    fn heartbeat_leaves_an_unreachable_lane_to_expire() {
        // Bind then drop: connects to this address are refused.
        let dead = {
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let mut l = lane(dead);
        l.last_ok = Instant::now() - Duration::from_millis(6_000);
        let before = l.last_ok;
        heartbeat_lane(&mut l, Duration::from_millis(10_000), Duration::from_millis(200));
        assert_eq!(l.last_ok, before, "failed ping must not refresh the lease");
        assert!(l.client.is_none());
    }
}
