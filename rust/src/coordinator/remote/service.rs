//! The `repro worker` daemon: accept suite cells over `SMMFCELL`,
//! execute them through the exact same
//! [`suite::execute_cell`](crate::coordinator::suite) path the local
//! thread pool uses, and answer polls with the job's state.
//!
//! Thread topology (all `std::thread`, mirroring `server::service`):
//!
//! * **acceptor** — non-blocking accept loop; spawns one handler thread
//!   per connection.
//! * **handlers** (one per connection) — strictly sequential frame →
//!   reply. A `Submit` past the concurrent-cell capacity is answered
//!   [`CellMsg::Busy`] immediately; the worker never queues unbounded
//!   work (the dispatcher owns the queue).
//! * **executors** (one per running cell) — train the cell, then record
//!   `Done` / `Failed` in the job table. A panicking cell is caught and
//!   recorded as `Failed` with a `FAILED` marker — same isolation
//!   contract as [`workers::fan_out_recover`](crate::coordinator::workers).
//!
//! Cells leave the *identical* on-disk artifacts a local run leaves
//! (`<out_dir>/<suite>/<run>/{metrics.jsonl,csv, summary.json}`,
//! `FAILED` on error), into paths resolved against the worker's working
//! directory. That is deliberate: the re-entry cache and the report
//! generator read only those files, so when coordinator and workers
//! share a filesystem (the loopback smoke / e2e setup) a completed
//! remote cell is indistinguishable from a completed local one.
//!
//! Submits are idempotent on the `(nonce, job)` pair: re-submitting a
//! known pair answers with the job's current state instead of training
//! twice — the dispatcher leans on this when it retries after a lost
//! reply. The nonce is drawn fresh per suite run, so against a
//! persistent daemon a second suite (or a `--force` re-run) that reuses
//! the same expansion indices is fresh work, never a stale verdict;
//! finished jobs from older nonces are pruned as new-nonce submits
//! arrive, bounding the table.
//!
//! `crash_after_accepts` is the chaos knob for the worker-death e2e: the
//! N-th accepted submit sets a `crashed` latch *without replying* and
//! every connection goes silent, exactly like a kill -9 — the
//! dispatcher's lease timeout has to notice and re-dispatch.

use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle};
use std::time::Duration;

use crate::coordinator::config::{ExperimentConfig, SuiteCell};
use crate::coordinator::remote::protocol::{self, CellFrame, CellMsg};
use crate::coordinator::suite::{self, CellStatus};
use crate::coordinator::workers::panic_note;

/// `repro worker` knobs.
#[derive(Clone, Debug)]
pub struct WorkerOptions {
    /// Listen address (`127.0.0.1:0` picks an ephemeral port).
    pub listen: String,
    /// Concurrent cells; a submit past this is answered `Busy`.
    pub capacity: usize,
    /// AOT artifacts directory for artifact-backed cells.
    pub artifacts_dir: String,
    /// Per-connection read/write timeouts (`None` = block forever).
    pub io_timeout: Option<Duration>,
    /// Chaos injector: go silent (no replies, ever again) the moment the
    /// N-th submit is accepted, stranding it mid-flight. `0` = never.
    pub crash_after_accepts: u64,
}

impl Default for WorkerOptions {
    fn default() -> Self {
        Self {
            listen: "127.0.0.1:0".into(),
            capacity: 1,
            artifacts_dir: "artifacts".into(),
            io_timeout: Some(Duration::from_secs(30)),
            crash_after_accepts: 0,
        }
    }
}

/// Final counters, printed by `repro worker` on shutdown.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct WorkerStats {
    /// Submits accepted (cells started).
    pub accepted: u64,
    /// Cells that finished with a finite-loss summary.
    pub done: u64,
    /// Cells that errored, diverged or panicked.
    pub failed: u64,
    /// Submits bounced at the capacity limit.
    pub busy: u64,
}

enum JobState {
    Running,
    Done,
    Failed(String),
}

struct Shared {
    /// Keyed by `(suite-run nonce, job)`: the nonce scopes idempotency
    /// to one dispatch, so job ids (expansion indices) reused by a
    /// later run never collide with an older run's verdicts.
    jobs: Mutex<HashMap<(u64, u64), (String, JobState)>>,
    shutdown: AtomicBool,
    /// The chaos latch: once set, every handler goes silent.
    crashed: AtomicBool,
    accepted: AtomicU64,
    done: AtomicU64,
    failed: AtomicU64,
    busy: AtomicU64,
    capacity: usize,
    artifacts_dir: String,
    crash_after_accepts: u64,
}

impl Shared {
    fn running(&self) -> u32 {
        let jobs = self.jobs.lock().unwrap();
        jobs.values().filter(|(_, s)| matches!(s, JobState::Running)).count() as u32
    }

    fn stats(&self) -> WorkerStats {
        WorkerStats {
            accepted: self.accepted.load(Ordering::Relaxed),
            done: self.done.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            busy: self.busy.load(Ordering::Relaxed),
        }
    }
}

/// A running cell-execution worker. [`WorkerServer::start`] returns once
/// the listener is bound; [`WorkerServer::wait`] blocks until a
/// [`CellMsg::Shutdown`] arrives, drains the running cells, and returns
/// the final counters.
pub struct WorkerServer {
    /// The bound address (resolves `:0` to the real ephemeral port).
    pub addr: SocketAddr,
    shared: Arc<Shared>,
    acceptor: Option<JoinHandle<()>>,
}

impl WorkerServer {
    /// Bind and start serving.
    pub fn start(opts: &WorkerOptions) -> Result<WorkerServer> {
        if opts.capacity == 0 {
            anyhow::bail!("worker capacity must be >= 1");
        }
        let listener = TcpListener::bind(&opts.listen)
            .with_context(|| format!("binding {}", opts.listen))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let shared = Arc::new(Shared {
            jobs: Mutex::new(HashMap::new()),
            shutdown: AtomicBool::new(false),
            crashed: AtomicBool::new(false),
            accepted: AtomicU64::new(0),
            done: AtomicU64::new(0),
            failed: AtomicU64::new(0),
            busy: AtomicU64::new(0),
            capacity: opts.capacity,
            artifacts_dir: opts.artifacts_dir.clone(),
            crash_after_accepts: opts.crash_after_accepts,
        });
        let acceptor = {
            let shared = shared.clone();
            let io_timeout = opts.io_timeout;
            thread::spawn(move || loop {
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                match listener.accept() {
                    Ok((stream, _)) => {
                        let shared = shared.clone();
                        thread::spawn(move || handle_conn(stream, shared, io_timeout));
                    }
                    // WouldBlock (idle) and transient accept errors both
                    // back off briefly; only the shutdown flag exits.
                    Err(_) => thread::sleep(Duration::from_millis(2)),
                }
            })
        };
        Ok(WorkerServer { addr, shared, acceptor: Some(acceptor) })
    }

    /// Current counters (live — callable while serving).
    pub fn stats(&self) -> WorkerStats {
        self.shared.stats()
    }

    /// Ask the worker to stop (same effect as a `Shutdown` frame).
    pub fn stop(&self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
    }

    /// Block until shutdown, drain running cells, return the counters.
    pub fn wait(mut self) -> WorkerStats {
        while !self.shared.shutdown.load(Ordering::SeqCst) {
            thread::sleep(Duration::from_millis(10));
        }
        // Graceful drain: let in-flight cells finish so their verdict
        // files land (a crashed worker skips this — that's the chaos).
        while !self.shared.crashed.load(Ordering::SeqCst) && self.shared.running() > 0 {
            thread::sleep(Duration::from_millis(10));
        }
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
        self.shared.stats()
    }
}

impl Drop for WorkerServer {
    fn drop(&mut self) {
        // Belt and braces: an abandoned handle must not keep the accept
        // loop spinning.
        self.shared.shutdown.store(true, Ordering::SeqCst);
    }
}

/// Rebuild the [`SuiteCell`] a submit describes. The config text is the
/// coordinator's canonical `to_toml` rendering, so `from_toml_str`
/// reproduces the resolved config exactly (pinned by the round-trip
/// test in `coordinator::config`). Paths are validated — a worker
/// executes with filesystem access, so a hostile `out_dir`/`name` must
/// die here, not in `create_dir_all`.
fn cell_from_submit(run: &str, model: &str, config: &str) -> Result<SuiteCell> {
    let cfg: ExperimentConfig = ExperimentConfig::from_toml_str(config)?;
    for (what, p) in [("name", cfg.name.as_str()), ("out_dir", cfg.out_dir.as_str())] {
        if p.is_empty() || p.starts_with('/') || p.split('/').any(|seg| seg == "..") {
            return Err(anyhow!("refusing cell {what} {p:?} (absolute or parent-escaping)"));
        }
    }
    Ok(SuiteCell {
        run: run.to_string(),
        model: model.to_string(),
        optimizer: cfg.optimizer,
        seed: cfg.seed,
        cfg,
    })
}

fn state_reply(job: u64, state: &JobState) -> CellMsg {
    match state {
        JobState::Running => CellMsg::Running { job },
        JobState::Done => CellMsg::Done { job },
        JobState::Failed(note) => {
            CellMsg::Failed { job, note: protocol::clip_str(note).to_string() }
        }
    }
}

/// Serve one submit: register the job, spawn its executor thread,
/// answer `Accepted`. Returns the reply to send.
fn handle_submit(
    shared: &Arc<Shared>,
    nonce: u64,
    job: u64,
    run: String,
    model: String,
    config: String,
) -> CellMsg {
    let key = (nonce, job);
    {
        let jobs = shared.jobs.lock().unwrap();
        // Idempotent re-submit (same suite run): answer with the
        // current state. The dispatcher hits this when a reply was lost
        // in flight. A different nonce never matches — a later run
        // reusing this job id is fresh work, not this verdict.
        if let Some((_, state)) = jobs.get(&key) {
            return match state {
                JobState::Running => CellMsg::Accepted { job },
                other => state_reply(job, other),
            };
        }
        if jobs.values().filter(|(_, s)| matches!(s, JobState::Running)).count()
            >= shared.capacity
        {
            shared.busy.fetch_add(1, Ordering::Relaxed);
            return CellMsg::Busy;
        }
    }
    let cell = match cell_from_submit(&run, &model, &config) {
        Ok(c) => c,
        Err(e) => return CellMsg::Err { msg: protocol::clip_str(&format!("{e:#}")).to_string() },
    };
    {
        let mut jobs = shared.jobs.lock().unwrap();
        // Re-check under the lock (another handler may have raced us in).
        if let Some((_, state)) = jobs.get(&key) {
            return match state {
                JobState::Running => CellMsg::Accepted { job },
                other => state_reply(job, other),
            };
        }
        if jobs.values().filter(|(_, s)| matches!(s, JobState::Running)).count()
            >= shared.capacity
        {
            shared.busy.fetch_add(1, Ordering::Relaxed);
            return CellMsg::Busy;
        }
        // A new nonce marks a new suite run: drop finished verdicts
        // from older nonces so the table stays bounded by the live
        // run's size. (If a *concurrent* coordinator loses a verdict to
        // this pruning, its poll gets `unknown job` and its dispatcher
        // requeues through the summary.json cache recheck — the on-disk
        // verdict, not this table, is the durable record.) Running jobs
        // are kept regardless; their executors still need somewhere to
        // record the verdict.
        jobs.retain(|&(n, _), (_, s)| n == nonce || matches!(s, JobState::Running));
        jobs.insert(key, (run.clone(), JobState::Running));
    }
    let n = shared.accepted.fetch_add(1, Ordering::SeqCst) + 1;
    println!("[worker] job {job} {run}: accepted ({model})");
    if shared.crash_after_accepts > 0 && n >= shared.crash_after_accepts {
        // Chaos: strand this job — no executor, no reply, total silence.
        println!("[worker] injected crash after {n} accept(s) — going silent");
        shared.crashed.store(true, Ordering::SeqCst);
        shared.shutdown.store(true, Ordering::SeqCst);
        return CellMsg::Busy; // never sent — the handler checks `crashed`
    }
    let shared = shared.clone();
    thread::spawn(move || {
        let tag = format!("[worker] job {job} {}", cell.run);
        let artifacts = shared.artifacts_dir.clone();
        let status = match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            suite::execute_cell(&tag, &cell, &artifacts)
        })) {
            Ok(s) => s,
            Err(payload) => suite::fail_cell(
                &tag,
                &suite::cell_dir(&cell),
                format!("cell worker panicked: {}", panic_note(payload.as_ref())),
            ),
        };
        let state = match status {
            CellStatus::Failed(note) => {
                shared.failed.fetch_add(1, Ordering::Relaxed);
                JobState::Failed(note)
            }
            // Ran, or Skipped (can't happen — execute_cell never skips);
            // either way the summary is on disk.
            _ => {
                shared.done.fetch_add(1, Ordering::Relaxed);
                JobState::Done
            }
        };
        shared.jobs.lock().unwrap().insert(key, (cell.run.clone(), state));
    });
    CellMsg::Accepted { job }
}

/// Per-connection handler: strictly sequential frame → reply.
fn handle_conn(stream: TcpStream, shared: Arc<Shared>, io_timeout: Option<Duration>) {
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(io_timeout).ok();
    stream.set_write_timeout(io_timeout).ok();
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = std::io::BufReader::new(read_half);
    let mut writer = std::io::BufWriter::new(stream);
    loop {
        // Read errors (EOF on disconnect, or a malformed frame) end the
        // connection; the protocol has no resync point.
        let Ok(frame) = protocol::read_frame(&mut reader) else { return };
        if shared.crashed.load(Ordering::SeqCst) {
            return; // the chaos latch: silence, not even an error reply
        }
        let id = frame.request_id;
        let reply = match frame.msg {
            CellMsg::Submit { nonce, job, run, model, config } => {
                handle_submit(&shared, nonce, job, run, model, config)
            }
            CellMsg::Poll { nonce, job } => {
                let jobs = shared.jobs.lock().unwrap();
                match jobs.get(&(nonce, job)) {
                    Some((_, state)) => state_reply(job, state),
                    None => CellMsg::Err { msg: format!("unknown job {job}") },
                }
            }
            CellMsg::Ping => {
                CellMsg::Pong { running: shared.running(), capacity: shared.capacity as u32 }
            }
            CellMsg::Shutdown => CellMsg::Bye,
            other => CellMsg::Err { msg: format!("{} is not a request", other.name()) },
        };
        if shared.crashed.load(Ordering::SeqCst) {
            return; // crash injected while handling — stay silent
        }
        let done = matches!(reply, CellMsg::Bye);
        if protocol::write_frame(&mut writer, &CellFrame { request_id: id, msg: reply }).is_err()
        {
            return;
        }
        if done {
            shared.shutdown.store(true, Ordering::SeqCst);
            return;
        }
    }
}
