//! Bench: per-step optimizer wall time over real model inventories —
//! regenerates the *shape* of the paper's Table 5 (optimizer-only time;
//! the paper measures full training steps on GPU, so absolute numbers
//! differ but the Adam-relative ratios are the claim under test).
//!
//! Also includes the SMMF ablation the perf pass optimizes against:
//! fused single-pass vs naive (materializing) implementation.
//!
//! ```bash
//! cargo bench --bench optimizer_step            # full
//! SMMF_BENCH_QUICK=1 cargo bench --bench optimizer_step
//! ```

use smmf_repro::models::inventory_by_name;
use smmf_repro::optim::{self, Optimizer, OptKind, OptimConfig, Smmf};
use smmf_repro::tensor::Tensor;
use smmf_repro::util::bench::Bencher;
use smmf_repro::util::fmt;
use smmf_repro::util::rng::Pcg32;

fn rand_tensors(shapes: &[Vec<usize>], seed: u64, scale: f32) -> Vec<Tensor> {
    let mut rng = Pcg32::new(seed);
    shapes
        .iter()
        .map(|s| {
            let mut t = Tensor::zeros(s);
            rng.fill_normal(t.data_mut(), scale);
            t
        })
        .collect()
}

fn main() {
    let quick = std::env::var("SMMF_BENCH_QUICK").is_ok();
    let bencher = if quick { Bencher::quick() } else { Bencher::default() };

    let models: &[&str] = if quick {
        &["mobilenet_v2_imagenet"]
    } else {
        &["mobilenet_v2_imagenet", "resnet50_imagenet", "transformer_base", "transformer_big"]
    };

    println!("== Table 5 proxy: optimizer step over full model inventories ==");
    for name in models {
        let inv = inventory_by_name(name).unwrap();
        let shapes = inv.shapes();
        let mut params = rand_tensors(&shapes, 1, 0.05);
        let grads = rand_tensors(&shapes, 2, 0.01);
        let mut adam_ms = f64::NAN;
        for kind in OptKind::all() {
            let cfg = OptimConfig::paper_defaults(kind);
            let mut opt = optim::build(kind, &shapes, &cfg);
            let stats = bencher.bench(&format!("{name}/{}", kind.name()), || {
                opt.step(&mut params, &grads)
            });
            if kind == OptKind::Adam {
                adam_ms = stats.median.as_secs_f64() * 1e3;
            }
            println!(
                "{}   ({:.2}x adam)",
                stats.summary(),
                stats.median.as_secs_f64() * 1e3 / adam_ms
            );
        }
        println!();
    }

    println!("== Ablation: SMMF fused single-pass vs naive (Algorithm-literal) ==");
    for &(n, m) in &[(512usize, 512usize), (2048, 2048), (5087, 4608)] {
        let shapes = vec![vec![n, m]];
        let cfg = OptimConfig::paper_defaults(OptKind::Smmf);
        let mut params = rand_tensors(&shapes, 1, 0.05);
        let grads = rand_tensors(&shapes, 2, 0.01);
        let mut fused = Smmf::new(&shapes, &cfg);
        let s1 = bencher.bench(&format!("smmf_fused/{n}x{m}"), || {
            fused.step(&mut params, &grads)
        });
        println!("{}", s1.summary());
        let mut naive = Smmf::new(&shapes, &cfg);
        let s2 = bencher.bench(&format!("smmf_naive/{n}x{m}"), || {
            naive.step_naive(&mut params, &grads)
        });
        println!(
            "{}   (fused is {:.2}x faster, scratch {} vs {})",
            s2.summary(),
            s2.median.as_secs_f64() / s1.median.as_secs_f64(),
            fmt::bytes(fused.scratch_bytes()),
            fmt::bytes(naive.scratch_bytes()),
        );
    }
}
