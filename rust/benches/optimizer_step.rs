//! Bench: per-step optimizer wall time over real model inventories —
//! regenerates the *shape* of the paper's Table 5 (optimizer-only time;
//! the paper measures full training steps on GPU, so absolute numbers
//! differ but the Adam-relative ratios are the claim under test).
//!
//! Sections:
//! 1. Table 5 proxy — every optimizer, serial (`threads = 1`) baseline.
//! 2. Parallel step engine thread sweep — SMMF and Adam at 1/2/4/8
//!    worker threads, reporting speedup vs the serial baseline.
//! 3. SMMF ablation — fused single-pass vs naive (Algorithm-literal).
//!
//! ```bash
//! cargo bench --bench optimizer_step            # full
//! SMMF_BENCH_QUICK=1 cargo bench --bench optimizer_step
//! SMMF_BENCH_JSON=BENCH_optimizer_step.json cargo bench --bench optimizer_step
//! ```
//!
//! With `SMMF_BENCH_JSON=<path>` a machine-readable report (per-model,
//! per-optimizer, per-thread-count median/p10/p90 ns) is written so the
//! perf trajectory is tracked across PRs.

use smmf_repro::models::inventory_by_name;
use smmf_repro::optim::group::{GroupedConfig, ParamRole};
use smmf_repro::optim::{self, memory, GroupPolicy, OptKind, OptimConfig, Optimizer, Smmf, StatePolicy};
use smmf_repro::tensor::Tensor;
use smmf_repro::util::bench::{Bencher, JsonSink};
use smmf_repro::util::fmt;
use smmf_repro::util::json::ObjBuilder;
use smmf_repro::util::rng::Pcg32;

fn rand_tensors(shapes: &[Vec<usize>], seed: u64, scale: f32) -> Vec<Tensor> {
    let mut rng = Pcg32::new(seed);
    shapes
        .iter()
        .map(|s| {
            let mut t = Tensor::zeros(s);
            rng.fill_normal(t.data_mut(), scale);
            t
        })
        .collect()
}

fn main() {
    let quick = std::env::var("SMMF_BENCH_QUICK").is_ok();
    let bencher = if quick { Bencher::quick() } else { Bencher::default() };
    let mut sink = JsonSink::from_env("optimizer_step", "SMMF_BENCH_JSON");

    let models: &[&str] = if quick {
        &["mobilenet_v2_imagenet"]
    } else {
        &["mobilenet_v2_imagenet", "resnet50_imagenet", "transformer_base", "transformer_big"]
    };

    println!("== Table 5 proxy: optimizer step over full model inventories (threads = 1) ==");
    for name in models {
        let inv = inventory_by_name(name).unwrap();
        let shapes = inv.shapes();
        let mut params = rand_tensors(&shapes, 1, 0.05);
        let grads = rand_tensors(&shapes, 2, 0.01);
        let mut adam_ms = f64::NAN;
        for kind in OptKind::all() {
            let cfg = OptimConfig::paper_defaults(kind);
            let mut opt = optim::build(kind, &shapes, &cfg);
            let stats = bencher.bench(&format!("{name}/{}", kind.name()), || {
                opt.step(&mut params, &grads)
            });
            if kind == OptKind::Adam {
                adam_ms = stats.median.as_secs_f64() * 1e3;
            }
            if let Some(s) = sink.as_mut() {
                s.record(name, kind.name(), 1, &stats);
            }
            println!(
                "{}   ({:.2}x adam)",
                stats.summary(),
                stats.median.as_secs_f64() * 1e3 / adam_ms
            );
        }
        println!();
    }

    // Thread sweep: the parallel step engine on the two headline
    // optimizers. Quick mode covers the acceptance model
    // (mobilenet_v2_imagenet); full mode adds transformer_big.
    let sweep_models: &[&str] =
        if quick { &["mobilenet_v2_imagenet"] } else { &["mobilenet_v2_imagenet", "transformer_big"] };
    println!("== Parallel step engine: thread sweep (speedup vs threads = 1) ==");
    for name in sweep_models {
        let inv = inventory_by_name(name).unwrap();
        let shapes = inv.shapes();
        let mut params = rand_tensors(&shapes, 1, 0.05);
        let grads = rand_tensors(&shapes, 2, 0.01);
        for kind in [OptKind::Smmf, OptKind::Adam] {
            let mut serial_ms = f64::NAN;
            for threads in [1usize, 2, 4, 8] {
                let mut cfg = OptimConfig::paper_defaults(kind);
                cfg.threads = threads;
                let mut opt = optim::build(kind, &shapes, &cfg);
                let label = format!("{name}/{}/t{threads}", kind.name());
                let stats = bencher.bench(&label, || opt.step(&mut params, &grads));
                let ms = stats.median.as_secs_f64() * 1e3;
                if threads == 1 {
                    serial_ms = ms;
                } else if let Some(s) = sink.as_mut() {
                    // threads = 1 for this (model, optimizer) is already
                    // recorded by the Table 5 section — don't duplicate
                    // the (model, optimizer, threads) key in the report.
                    s.record(name, kind.name(), threads, &stats);
                }
                println!("{}   ({:.2}x vs serial)", stats.summary(), serial_ms / ms);
            }
        }
        println!();
    }

    // Grouped vs uniform: the paper-faithful recipe (bias/norm
    // weight-decay exemption + dense Adam-style state for rank-1
    // tensors) against the flat config, on the same inventory. The
    // ratio lands in the JSON trajectory: group resolution is
    // construction-time work, so the grouped step should cost ~the
    // uniform step (dense rank-1 state trades factor math for moment
    // math on a tiny fraction of the elements).
    println!("== Grouped vs uniform SMMF step (bias/norm wd-exempt, dense rank-1) ==");
    {
        let name = "mobilenet_v2_imagenet";
        let inv = inventory_by_name(name).unwrap();
        let shapes = inv.shapes();
        let specs = inv.param_specs();
        let mut params = rand_tensors(&shapes, 1, 0.05);
        let grads = rand_tensors(&shapes, 2, 0.01);
        let base = OptimConfig {
            weight_decay: 1e-4,
            ..OptimConfig::paper_defaults(OptKind::Smmf)
        };
        let mut uniform = optim::build(OptKind::Smmf, &shapes, &base);
        let s_uniform = bencher.bench(&format!("{name}/smmf_uniform"), || {
            uniform.step(&mut params, &grads)
        });
        println!("{}", s_uniform.summary());
        let mut gcfg = GroupedConfig::uniform(&base);
        gcfg.groups.push(GroupPolicy {
            name: "no_decay_dense".into(),
            match_roles: vec![ParamRole::Bias, ParamRole::Norm],
            weight_decay: Some(0.0),
            state: StatePolicy::Dense,
            ..GroupPolicy::default()
        });
        let mut grouped = optim::build_grouped(OptKind::Smmf, &specs, &gcfg);
        let s_grouped = bencher.bench(&format!("{name}/smmf_grouped"), || {
            grouped.step(&mut params, &grads)
        });
        let ratio =
            s_grouped.median.as_secs_f64() / s_uniform.median.as_secs_f64();
        println!("{}   ({ratio:.2}x vs uniform)", s_grouped.summary());
        if let Some(s) = sink.as_mut() {
            s.record(name, "smmf_uniform", 1, &s_uniform);
            s.record(name, "smmf_grouped", 1, &s_grouped);
            s.push(
                ObjBuilder::new()
                    .str("name", &format!("grouped_vs_uniform/{name}"))
                    .str("model", name)
                    .num("uniform_median_ns", s_uniform.median.as_secs_f64() * 1e9)
                    .num("grouped_median_ns", s_grouped.median.as_secs_f64() * 1e9)
                    .num("grouped_vs_uniform_ratio", ratio)
                    .build(),
            );
        }
        println!();
    }

    println!("== Ablation: SMMF fused single-pass vs naive (Algorithm-literal) ==");
    for &(n, m) in &[(512usize, 512usize), (2048, 2048), (5087, 4608)] {
        let shapes = vec![vec![n, m]];
        let cfg = OptimConfig::paper_defaults(OptKind::Smmf);
        let mut params = rand_tensors(&shapes, 1, 0.05);
        let grads = rand_tensors(&shapes, 2, 0.01);
        let mut fused = Smmf::new(&shapes, &cfg);
        let s1 = bencher.bench(&format!("smmf_fused/{n}x{m}"), || {
            fused.step(&mut params, &grads)
        });
        println!("{}", s1.summary());
        if let Some(s) = sink.as_mut() {
            s.record(&format!("{n}x{m}"), "smmf_fused", 1, &s1);
        }
        let mut naive = Smmf::new(&shapes, &cfg);
        let s2 = bencher.bench(&format!("smmf_naive/{n}x{m}"), || {
            naive.step_naive(&mut params, &grads)
        });
        if let Some(s) = sink.as_mut() {
            s.record(&format!("{n}x{m}"), "smmf_naive", 1, &s2);
        }
        println!(
            "{}   (fused is {:.2}x faster, scratch {} vs {})",
            s2.summary(),
            s2.median.as_secs_f64() / s1.median.as_secs_f64(),
            fmt::bytes(fused.scratch_bytes()),
            fmt::bytes(naive.scratch_bytes()),
        );
    }

    // Checkpoint size: the on-disk optimizer-state section of a SMMFCKPT
    // v2 checkpoint (native StateSerde serialization, analytic mirror in
    // optim::memory) for SMMF vs Adam over the same inventories. The
    // SMMF-vs-Adam ratio goes into the JSON trajectory; the paper's
    // memory claim must carry over to disk (ratio well under 0.10).
    println!("\n== Checkpoint size: optimizer-state section, SMMF vs Adam ==");
    for name in models {
        let inv = inventory_by_name(name).unwrap();
        let shapes = inv.shapes();
        let smmf_b = memory::inventory_checkpoint_bytes(
            OptKind::Smmf,
            &shapes,
            &OptimConfig::paper_defaults(OptKind::Smmf),
        );
        let adam_b = memory::inventory_checkpoint_bytes(
            OptKind::Adam,
            &shapes,
            &OptimConfig::paper_defaults(OptKind::Adam),
        );
        let ratio = smmf_b as f64 / adam_b as f64;
        println!(
            "{name:<28} smmf {:>12}  adam {:>12}  ratio {ratio:.4}",
            fmt::bytes(smmf_b),
            fmt::bytes(adam_b),
        );
        if let Some(s) = sink.as_mut() {
            s.push(
                ObjBuilder::new()
                    .str("name", &format!("checkpoint_size/{name}"))
                    .str("model", name)
                    .num("smmf_ckpt_bytes", smmf_b as f64)
                    .num("adam_ckpt_bytes", adam_b as f64)
                    .num("smmf_vs_adam_ratio", ratio)
                    .build(),
            );
        }
    }

    if let Some(s) = sink {
        match s.write() {
            Ok(()) => println!("\nwrote {} bench records to {}", s.len(), s.path().display()),
            Err(e) => eprintln!("\nfailed to write {}: {e}", s.path().display()),
        }
    }
}
