//! In-tree stub of the `xla` (PJRT) bindings.
//!
//! The offline build environment does not ship the real `xla` crate, so
//! this stub provides the same API surface the repository uses:
//!
//! * [`Literal`] is a **real** host-side implementation (typed f32 / i32 /
//!   PRED buffers with a shape) — everything that constructs, reshapes,
//!   reads back or sizes literals works exactly, so the pure-Rust training
//!   stack and its tests are fully functional.
//! * Compilation/execution ([`PjRtClient::compile`],
//!   [`PjRtLoadedExecutable::execute`], [`HloModuleProto::from_text_file`])
//!   returns a descriptive [`Error`]. The runtime layer already treats a
//!   missing artifact directory as "self-skip", so integration paths
//!   degrade gracefully instead of failing the build.

use std::path::Path;

/// Stub error type; call sites format it with `{:?}`.
pub struct Error(pub String);

impl std::fmt::Debug for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what} is unavailable: this build uses the in-tree xla stub (no PJRT backend); \
         run with the real xla crate to execute AOT artifacts"
    ))
}

/// XLA element types crossing the host boundary.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    Pred,
}

/// Host types storable in a [`Literal`].
pub trait NativeType: Copy + Sized + 'static {
    const ELEMENT_TYPE: ElementType;
    fn wrap(data: Vec<Self>) -> Data;
    fn unwrap(data: &Data) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    const ELEMENT_TYPE: ElementType = ElementType::F32;
    fn wrap(data: Vec<f32>) -> Data {
        Data::F32(data)
    }
    fn unwrap(data: &Data) -> Option<Vec<f32>> {
        match data {
            Data::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    const ELEMENT_TYPE: ElementType = ElementType::S32;
    fn wrap(data: Vec<i32>) -> Data {
        Data::I32(data)
    }
    fn unwrap(data: &Data) -> Option<Vec<i32>> {
        match data {
            Data::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

/// Typed literal storage.
#[derive(Clone, Debug)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Pred(Vec<u8>),
    Tuple(Vec<Literal>),
}

/// A host tensor value (shape + typed buffer), API-compatible with the
/// real crate's `Literal` for the operations this repository performs.
#[derive(Clone, Debug)]
pub struct Literal {
    shape: Vec<usize>,
    data: Data,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { shape: vec![data.len()], data: T::wrap(data.to_vec()) }
    }

    /// Rank-0 literal.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal { shape: vec![], data: T::wrap(vec![v]) }
    }

    /// Reinterpret with a new shape (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, Error> {
        let shape: Vec<usize> = dims.iter().map(|&d| d.max(0) as usize).collect();
        let numel: usize = shape.iter().product();
        if numel != self.numel() {
            return Err(Error(format!(
                "reshape {:?} -> {dims:?}: element count mismatch",
                self.shape
            )));
        }
        Ok(Literal { shape, data: self.data.clone() })
    }

    /// Build from raw bytes (used for PRED tensors: one byte per element).
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        shape: &[usize],
        bytes: &[u8],
    ) -> Result<Literal, Error> {
        let numel: usize = shape.iter().product();
        let data = match ty {
            ElementType::Pred => {
                if bytes.len() != numel {
                    return Err(Error(format!(
                        "pred literal: {} bytes for {numel} elements",
                        bytes.len()
                    )));
                }
                Data::Pred(bytes.to_vec())
            }
            ElementType::F32 => {
                if bytes.len() != numel * 4 {
                    return Err(Error(format!(
                        "f32 literal: {} bytes for {numel} elements",
                        bytes.len()
                    )));
                }
                Data::F32(
                    bytes
                        .chunks_exact(4)
                        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect(),
                )
            }
            ElementType::S32 => {
                if bytes.len() != numel * 4 {
                    return Err(Error(format!(
                        "i32 literal: {} bytes for {numel} elements",
                        bytes.len()
                    )));
                }
                Data::I32(
                    bytes
                        .chunks_exact(4)
                        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect(),
                )
            }
        };
        Ok(Literal { shape: shape.to_vec(), data })
    }

    pub fn numel(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::Pred(v) => v.len(),
            Data::Tuple(t) => t.iter().map(|l| l.numel()).sum(),
        }
    }

    /// Total buffer bytes (PRED is one byte per element, like XLA).
    pub fn size_bytes(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len() * 4,
            Data::I32(v) => v.len() * 4,
            Data::Pred(v) => v.len(),
            Data::Tuple(t) => t.iter().map(|l| l.size_bytes()).sum(),
        }
    }

    /// Copy out as a host vector of `T`.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        T::unwrap(&self.data).ok_or_else(|| Error(format!("to_vec: wrong dtype {:?}", self.data)))
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T, Error> {
        T::unwrap(&self.data)
            .and_then(|v| v.first().copied())
            .ok_or_else(|| Error("get_first_element: empty or wrong dtype".into()))
    }

    /// Destructure a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        match self.data {
            Data::Tuple(t) => Ok(t),
            _ => Err(Error("to_tuple on a non-tuple literal".into())),
        }
    }

    pub fn shape_dims(&self) -> &[usize] {
        &self.shape
    }
}

/// Parsed HLO module (stub: parsing requires the real backend).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto, Error> {
        Err(unavailable(&format!("parsing HLO text {:?}", path.as_ref())))
    }
}

/// An XLA computation (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// PJRT device buffer handle (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable("device-to-host transfer"))
    }
}

/// Compiled executable handle (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable("executable execution"))
    }
}

/// PJRT client handle. `cpu()` succeeds so that manifest-driven tooling
/// (e.g. `repro list`) can open artifact directories; compiling fails
/// with a descriptive error.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Ok(PjRtClient)
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable("XLA compilation"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32_i32() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(l.size_bytes(), 16);
        let i = Literal::vec1(&[5i32, -6]);
        assert_eq!(i.to_vec::<i32>().unwrap(), vec![5, -6]);
        assert!(i.to_vec::<f32>().is_err());
    }

    #[test]
    fn pred_bytes() {
        let p =
            Literal::create_from_shape_and_untyped_data(ElementType::Pred, &[3], &[1, 0, 1])
                .unwrap();
        assert_eq!(p.size_bytes(), 3);
    }

    #[test]
    fn scalar_first_element() {
        let s = Literal::scalar(7.5f32);
        assert_eq!(s.get_first_element::<f32>().unwrap(), 7.5);
    }

    #[test]
    fn execution_is_stubbed() {
        let client = PjRtClient::cpu().unwrap();
        assert!(client.compile(&XlaComputation).is_err());
        assert!(HloModuleProto::from_text_file("/tmp/x.hlo").is_err());
    }
}
