//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this vendored crate
//! implements the (small) subset of the real `anyhow` API the repository
//! uses: [`Error`], [`Result`], the [`anyhow!`] / [`bail!`] macros and the
//! [`Context`] extension trait. Error values carry a context chain of
//! messages; `{:#}` formatting prints the full chain like real anyhow.

use std::fmt;

/// A boxed-free error: an ordered chain of messages, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a single message (what `anyhow!` expands to).
    pub fn msg(message: impl fmt::Display) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Prepend a context message (what `Context::context` does).
    pub fn context(mut self, message: impl fmt::Display) -> Error {
        self.chain.insert(0, message.to_string());
        self
    }

    /// The context/cause messages, outermost first.
    pub fn chain_messages(&self) -> &[String] {
        &self.chain
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        for cause in &self.chain[1.min(self.chain.len())..] {
            write!(f, "\n\nCaused by:\n    {cause}")?;
        }
        Ok(())
    }
}

// Like real anyhow: any std error converts via `?`. `Error` itself does
// NOT implement `std::error::Error`, which is what keeps this blanket
// impl coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>` — `Result<T, anyhow::Error>` with the usual default.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors (subset of anyhow's `Context` trait).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: Into<Error>,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(context)
        })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(f())
        })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)+) => {
        $crate::Error::msg(format!($($arg)+))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return Err($crate::anyhow!($($arg)+))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn format_chain() {
        let e: Error = Err::<(), _>(io_err()).with_context(|| "opening config").unwrap_err();
        assert_eq!(format!("{e}"), "opening config");
        assert_eq!(format!("{e:#}"), "opening config: missing");
    }

    #[test]
    fn macros_and_question_mark() {
        fn inner() -> Result<u32> {
            let _f = std::fs::metadata("/definitely/not/a/path/8b1f")?;
            bail!("unreachable {}", 1);
        }
        assert!(inner().is_err());
        let e = anyhow!("code {}", 7);
        assert_eq!(e.to_string(), "code 7");
    }

    #[test]
    fn option_context() {
        let v: Result<u32> = None.context("empty");
        assert_eq!(v.unwrap_err().to_string(), "empty");
    }
}
