//! `SMMFCELL` wire-protocol tests through the public codec API:
//! roundtrips, the strict-decode rejection matrix (bad magic/version,
//! oversized length claims, truncation, trailing bytes, string caps),
//! and a live socket exchange between [`CellClient`] and a
//! [`WorkerServer`] to pin the framing end-to-end.

use smmf_repro::coordinator::remote::protocol::{
    self, CellFrame, CellMsg, HEADER_LEN, MAX_CONFIG_LEN, MAX_PAYLOAD, MAX_STR_LEN,
};
use smmf_repro::coordinator::remote::{CellClient, WorkerOptions, WorkerServer};

fn frame(id: u64, msg: CellMsg) -> CellFrame {
    CellFrame { request_id: id, msg }
}

fn sample_msgs() -> Vec<CellMsg> {
    vec![
        CellMsg::Submit {
            nonce: 0x1234_5678_9ABC_DEF0,
            job: 0,
            run: "tiny_lm-adam-s0".into(),
            model: "synthetic:tiny_lm".into(),
            config: "name = \"smoke/tiny_lm-adam-s0\"\n[train]\nsteps = 8\n".into(),
        },
        CellMsg::Poll { nonce: 0x1234_5678_9ABC_DEF0, job: 3 },
        CellMsg::Ping,
        CellMsg::Shutdown,
        CellMsg::Accepted { job: 0 },
        CellMsg::Running { job: 0 },
        CellMsg::Done { job: 0 },
        CellMsg::Failed { job: 0, note: "diverged: non-finite loss after 8 steps".into() },
        CellMsg::Busy,
        CellMsg::Pong { running: 2, capacity: 4 },
        CellMsg::Bye,
        CellMsg::Err { msg: "unknown job 9".into() },
    ]
}

#[test]
fn all_messages_roundtrip_with_ids() {
    for (i, msg) in sample_msgs().into_iter().enumerate() {
        let f = frame(0xABCD_0000 + i as u64, msg);
        let bytes = protocol::encode(&f);
        assert_eq!(&bytes[..8], protocol::MAGIC, "magic leads every frame");
        assert!(bytes.len() >= HEADER_LEN);
        let back = protocol::decode(&bytes).unwrap();
        assert_eq!(back, f, "frame {i}");
    }
}

#[test]
fn corruption_matrix_is_rejected_with_context() {
    let good = protocol::encode(&frame(9, CellMsg::Poll { nonce: 1, job: 7 }));

    // Bad magic — the defense against cross-protocol confusion with
    // SMMFWIRE, whose header layout is identical.
    let mut b = good.clone();
    b[..8].copy_from_slice(b"SMMFWIRE");
    let e = protocol::decode(&b).unwrap_err().to_string();
    assert!(e.contains("bad magic"), "{e}");

    // Future version.
    let mut b = good.clone();
    b[8..12].copy_from_slice(&2u32.to_le_bytes());
    let e = protocol::decode(&b).unwrap_err().to_string();
    assert!(e.contains("version 2"), "{e}");

    // A length claim over the cap must die in the header, before any
    // payload allocation.
    let mut b = good.clone();
    b[21..29].copy_from_slice(&(MAX_PAYLOAD + 1).to_le_bytes());
    let e = protocol::decode(&b).unwrap_err().to_string();
    assert!(e.contains("cap"), "{e}");

    // Truncation at every boundary.
    for cut in [0, 1, HEADER_LEN - 1, HEADER_LEN, good.len() - 1] {
        assert!(protocol::decode(&good[..cut]).is_err(), "cut at {cut} accepted");
    }

    // Trailing bytes after a complete frame.
    let mut b = good.clone();
    b.push(0);
    let e = protocol::decode(&b).unwrap_err().to_string();
    assert!(e.contains("trailing"), "{e}");

    // Unknown op.
    let mut b = good;
    b[20] = 200;
    assert!(protocol::decode(&b).unwrap_err().to_string().contains("unknown"), "op 200");
}

#[test]
fn string_and_config_caps_are_enforced() {
    // A config right at the cap encodes and decodes.
    let config = "x".repeat(MAX_CONFIG_LEN);
    let f = frame(
        1,
        CellMsg::Submit { nonce: 5, job: 1, run: "r".into(), model: "m".into(), config },
    );
    let bytes = protocol::encode(&f);
    assert_eq!(protocol::decode(&bytes).unwrap(), f);

    // One byte over the cap is rejected by the decoder — and caught
    // locally, by field name, by the pre-flight limit check the
    // dispatcher and CellClient::submit run before framing.
    let over_config = "x".repeat(MAX_CONFIG_LEN + 1);
    let e = protocol::check_submit_limits("r", "m", &over_config).unwrap_err().to_string();
    assert!(e.contains("Submit.config") && e.contains("cap"), "{e}");
    let over = frame(
        2,
        CellMsg::Submit {
            nonce: 5,
            job: 2,
            run: "r".into(),
            model: "m".into(),
            config: over_config,
        },
    );
    let e = protocol::decode(&protocol::encode(&over)).unwrap_err().to_string();
    assert!(e.contains("cap"), "{e}");

    // Outgoing notes are clipped (char-boundary safe), so a kilometer
    // of anyhow context can never build an undecodable frame.
    let long_note = "é".repeat(MAX_STR_LEN);
    let f = frame(3, CellMsg::Failed { job: 3, note: long_note.clone() });
    let back = protocol::decode(&protocol::encode(&f)).unwrap();
    match back.msg {
        CellMsg::Failed { note, .. } => {
            assert!(note.len() <= MAX_STR_LEN);
            assert!(long_note.starts_with(&note));
        }
        other => panic!("expected Failed, got {}", other.name()),
    }
}

#[test]
fn request_and_reply_ops_are_disjoint() {
    for msg in sample_msgs() {
        let is_req = matches!(
            msg,
            CellMsg::Submit { .. } | CellMsg::Poll { .. } | CellMsg::Ping | CellMsg::Shutdown
        );
        assert_eq!(msg.is_request(), is_req, "{}", msg.name());
    }
}

#[test]
fn stream_framing_survives_back_to_back_frames() {
    let frames: Vec<CellFrame> =
        sample_msgs().into_iter().enumerate().map(|(i, m)| frame(i as u64, m)).collect();
    let mut buf = Vec::new();
    for f in &frames {
        protocol::write_frame(&mut buf, f).unwrap();
    }
    let mut r = &buf[..];
    for f in &frames {
        assert_eq!(&protocol::read_frame(&mut r).unwrap(), f);
    }
    assert!(r.is_empty(), "no residue between frames");
}

#[test]
fn live_socket_ping_pong_and_error_replies() {
    let server = WorkerServer::start(&WorkerOptions {
        capacity: 3,
        ..WorkerOptions::default()
    })
    .unwrap();
    let addr = server.addr.to_string();
    let mut c = CellClient::connect(&addr, Some(std::time::Duration::from_secs(5))).unwrap();
    assert_eq!(c.ping().unwrap(), (0, 3), "idle worker, capacity 3");
    // Unknown job id -> typed Err, connection stays usable.
    match c.poll(1, 42).unwrap() {
        CellMsg::Err { msg } => assert!(msg.contains("unknown job 42"), "{msg}"),
        other => panic!("expected Err, got {}", other.name()),
    }
    // A reply op sent as a request is refused by name (raw socket —
    // CellClient refuses to send non-requests at all).
    {
        let mut raw = std::net::TcpStream::connect(&addr).unwrap();
        protocol::write_frame(&mut raw, &frame(77, CellMsg::Busy)).unwrap();
        let reply = protocol::read_frame(&mut raw).unwrap();
        assert_eq!(reply.request_id, 77, "replies echo the request id");
        match reply.msg {
            CellMsg::Err { msg } => assert!(msg.contains("Busy is not a request"), "{msg}"),
            other => panic!("expected Err, got {}", other.name()),
        }
    }
    // DNS hostnames are part of the advertised `remote:HOST:PORT`
    // grammar: dialing via `localhost` (resolver, not an IP literal)
    // must reach the same worker.
    let by_name = format!("localhost:{}", server.addr.port());
    let mut c2 =
        CellClient::connect(&by_name, Some(std::time::Duration::from_secs(5))).unwrap();
    assert_eq!(c2.ping().unwrap(), (0, 3), "hostname dial reaches the worker");
    c.shutdown().unwrap();
    let stats = server.wait();
    assert_eq!(stats.accepted, 0);
}
