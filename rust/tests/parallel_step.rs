//! Differential tests for the parallel optimizer step engine: for every
//! optimizer in the suite, `threads = N` must reproduce `threads = 1`.
//!
//! * Elementwise and tensor-granular optimizers (Adam/AdamW, SGD,
//!   Adafactor, CAME, SM3, SMMF's dense fallback): bit-exact.
//! * SMMF's factored fused path: bit-exact across any `threads >= 2`
//!   (fixed shard plan — item boundaries are thread-count independent),
//!   and within 1e-6 relative of `threads = 1` (the serial path folds
//!   the column accumulators in a single pass, so only the FP reduction
//!   order differs).

use smmf_repro::optim::{self, OptKind, OptimConfig, SignMode};
use smmf_repro::tensor::Tensor;
use smmf_repro::util::rng::Pcg32;

fn rand_tensors(rng: &mut Pcg32, shapes: &[Vec<usize>], scale: f32) -> Vec<Tensor> {
    shapes
        .iter()
        .map(|s| {
            let mut t = Tensor::zeros(s);
            rng.fill_normal(t.data_mut(), scale);
            t
        })
        .collect()
}

/// Rank-1 / rank-2 / rank-4 shapes next to 1-element biases — the
/// adversarial mix the partition planner must cover exactly once.
fn shapes() -> Vec<Vec<usize>> {
    vec![
        vec![2048],          // rank 1
        vec![1],             // 1-element bias
        vec![96, 80],        // rank 2
        vec![17, 3],         // odd rank 2
        vec![16, 8, 3, 3],   // rank 4 (conv-like)
        vec![4, 4, 1, 1],    // 1x1 conv pathology
        vec![257],           // prime length vector
    ]
}

fn run_trajectory(kind: OptKind, cfg: &OptimConfig, steps: usize) -> Vec<Tensor> {
    let shapes = shapes();
    let mut rng = Pcg32::new(0xabcd);
    let mut params = rand_tensors(&mut rng, &shapes, 0.5);
    let mut opt = optim::build(kind, &shapes, cfg);
    assert!(opt.partition().is_some(), "{}: no shard plan", kind.name());
    for _ in 0..steps {
        let grads = rand_tensors(&mut rng, &shapes, 0.1);
        opt.step(&mut params, &grads);
    }
    params
}

fn assert_close(kind: OptKind, a: &[Tensor], b: &[Tensor], tol: f32) {
    for (ta, tb) in a.iter().zip(b) {
        for (x, y) in ta.data().iter().zip(tb.data()) {
            assert!(
                (x - y).abs() <= tol * x.abs().max(1.0),
                "{}: {x} vs {y} (tol {tol})",
                kind.name()
            );
        }
    }
}

#[test]
fn every_optimizer_matches_serial_under_threads() {
    let kinds = [
        OptKind::Sgd,
        OptKind::Adam,
        OptKind::AdamW,
        OptKind::Adafactor,
        OptKind::Sm3,
        OptKind::Came,
        OptKind::Smmf,
    ];
    for kind in kinds {
        let base = OptimConfig {
            lr: 0.01,
            weight_decay: 0.01,
            relative_step: false,
            ..OptimConfig::paper_defaults(kind)
        };
        let serial = run_trajectory(kind, &base, 3);
        for threads in [2usize, 4, 8] {
            let par = run_trajectory(kind, &OptimConfig { threads, ..base.clone() }, 3);
            if kind == OptKind::Smmf {
                // Factored path: reduction-order tolerance vs serial...
                assert_close(kind, &serial, &par, 1e-6);
            } else {
                // ...everything else is bit-exact.
                assert_eq!(serial, par, "{} threads={threads}", kind.name());
            }
        }
    }
}

#[test]
fn smmf_parallel_bit_exact_for_fixed_plan() {
    // Item boundaries are thread-count independent, so every threads >= 2
    // executes the same shard plan and must agree bit-for-bit.
    for sign_mode in [SignMode::Bit1, SignMode::Byte8] {
        for vector_reshape in [true, false] {
            let mk = |threads: usize| OptimConfig {
                lr: 0.01,
                weight_decay: 0.01,
                smmf_sign_mode: sign_mode,
                vector_reshape,
                threads,
                ..OptimConfig::paper_defaults(OptKind::Smmf)
            };
            let t2 = run_trajectory(OptKind::Smmf, &mk(2), 3);
            let t4 = run_trajectory(OptKind::Smmf, &mk(4), 3);
            let t8 = run_trajectory(OptKind::Smmf, &mk(8), 3);
            assert_eq!(t2, t4, "sign={sign_mode:?} vr={vector_reshape}");
            assert_eq!(t4, t8, "sign={sign_mode:?} vr={vector_reshape}");
        }
    }
}

#[test]
fn smmf_variants_match_serial_under_threads() {
    // Both sign widths and the dense rank-1 fallback, vs threads = 1.
    // The dense fallback is elementwise, so with vector_reshape = false
    // the rank-1 tensors are bit-exact; factored tensors stay within
    // reduction-order tolerance.
    for sign_mode in [SignMode::Bit1, SignMode::Byte8] {
        for vector_reshape in [true, false] {
            let mk = |threads: usize| OptimConfig {
                lr: 0.01,
                smmf_sign_mode: sign_mode,
                vector_reshape,
                threads,
                ..OptimConfig::paper_defaults(OptKind::Smmf)
            };
            let serial = run_trajectory(OptKind::Smmf, &mk(1), 3);
            let par = run_trajectory(OptKind::Smmf, &mk(4), 3);
            assert_close(OptKind::Smmf, &serial, &par, 1e-6);
        }
    }
}

#[test]
fn state_accounting_is_thread_invariant() {
    // The engine adds transient scratch, never persistent state: the
    // paper's memory tables must not depend on the thread count.
    let shapes = shapes();
    for kind in OptKind::all() {
        let cfg1 = OptimConfig::paper_defaults(kind);
        let cfg4 = OptimConfig { threads: 4, ..OptimConfig::paper_defaults(kind) };
        let o1 = optim::build(kind, &shapes, &cfg1);
        let o4 = optim::build(kind, &shapes, &cfg4);
        assert_eq!(o1.state_bytes(), o4.state_bytes(), "{}", kind.name());
    }
}

#[test]
fn quadratic_minimization_still_works_parallel() {
    // The mod.rs smoke test, under the engine: every optimizer reduces a
    // convex quadratic with threads = 4.
    let shapes = vec![vec![4, 3], vec![6]];
    for kind in OptKind::all() {
        let cfg = OptimConfig {
            lr: 0.05,
            relative_step: false,
            threads: 4,
            ..OptimConfig::paper_defaults(kind)
        };
        let mut opt = optim::build(kind, &shapes, &cfg);
        let mut params: Vec<Tensor> = shapes
            .iter()
            .map(|s| {
                let n: usize = s.iter().product();
                Tensor::from_vec(s, (0..n).map(|i| 1.0 + (i % 3) as f32).collect())
            })
            .collect();
        let loss = |ps: &[Tensor]| -> f64 { ps.iter().map(|p| p.sq_norm()).sum() };
        let initial = loss(&params);
        for _ in 0..1500 {
            let grads: Vec<Tensor> = params
                .iter()
                .map(|p| {
                    let mut g = p.clone();
                    g.scale(2.0);
                    g
                })
                .collect();
            opt.step(&mut params, &grads);
        }
        let fin = loss(&params);
        assert!(fin < initial * 0.1, "{}: {initial} -> {fin}", kind.name());
    }
}
