//! Property tests for the v4 chunk-streaming layer against *real*
//! optimizer state: every optimizer's native state blobs must survive
//! chunking under random chunk budgets, row splits and arrival
//! permutations — byte-exact — and hostile stream shapes (duplicates,
//! overlaps, dropped chunks) must be rejected with typed
//! [`ChunkError`]s, not panics or silent corruption. This is the
//! factored-pull data path: the exact bytes `Smmf::state_blob` emits
//! are what a [`PULL_FACTORED`] stream carries.

use smmf_repro::optim::{self, OptKind, OptimConfig};
use smmf_repro::server::protocol::{chunk_plan, ChunkAssembler, ChunkError, CHUNK_MAX_BYTES};
use smmf_repro::tensor::Tensor;
use smmf_repro::util::prop;
use smmf_repro::util::rng::Pcg32;

const ALL_KINDS: [OptKind; 7] = [
    OptKind::Sgd,
    OptKind::Adam,
    OptKind::AdamW,
    OptKind::Adafactor,
    OptKind::Sm3,
    OptKind::Came,
    OptKind::Smmf,
];

/// Shapes covering the interesting cases: 2-D (factored under SMMF),
/// vector, scalar-ish, and a second matrix with different geometry.
fn shapes() -> Vec<Vec<usize>> {
    vec![vec![6, 4], vec![5], vec![1], vec![3, 8]]
}

/// Build `kind`, run a few deterministic steps so the state is
/// non-trivial, return its native per-tensor blobs.
fn stepped_blobs(kind: OptKind) -> Vec<Vec<u8>> {
    let shapes = shapes();
    let cfg = OptimConfig { lr: 1e-2, momentum: 0.9, ..Default::default() };
    let mut opt = optim::build(kind, &shapes, &cfg);
    let mut rng = Pcg32::new(0xb10b ^ kind as u64);
    let mut params: Vec<Tensor> = shapes
        .iter()
        .map(|s| {
            let mut t = Tensor::zeros(s);
            rng.fill_normal(t.data_mut(), 0.5);
            t
        })
        .collect();
    for _ in 0..3 {
        let grads: Vec<Tensor> = shapes
            .iter()
            .map(|s| {
                let mut t = Tensor::zeros(s);
                rng.fill_normal(t.data_mut(), 0.1);
                t
            })
            .collect();
        opt.step(&mut params, &grads);
    }
    opt.state_blobs()
}

/// One chunk job: everything needed to emit a header + data pair.
#[derive(Clone, Copy)]
struct Job {
    tensor: u32,
    seq: u32,
    total: u32,
    start: u64,
    count: u64,
    len: u64,
}

fn jobs_for(blobs: &[Vec<u8>], budget: u64, row_bytes: u64) -> Vec<Job> {
    let mut jobs = Vec::new();
    for (t, b) in blobs.iter().enumerate() {
        let plan = chunk_plan(b.len() as u64, row_bytes, budget);
        for (seq, &(start, count)) in plan.iter().enumerate() {
            jobs.push(Job {
                tensor: t as u32,
                seq: seq as u32,
                total: plan.len() as u32,
                start,
                count,
                len: b.len() as u64,
            });
        }
    }
    jobs
}

fn shuffle<T>(v: &mut [T], rng: &mut Pcg32) {
    for i in (1..v.len()).rev() {
        v.swap(i, rng.below(i + 1));
    }
}

fn feed(asm: &mut ChunkAssembler, blobs: &[Vec<u8>], j: Job) -> Result<(), ChunkError> {
    asm.header(j.tensor, j.seq, j.total, j.start, j.count, j.len)?;
    let b = &blobs[j.tensor as usize];
    asm.data(j.tensor, j.seq, &b[j.start as usize..(j.start + j.count) as usize])
}

#[test]
fn prop_every_optimizer_state_roundtrips_under_random_streams() {
    let per_kind: Vec<(OptKind, Vec<Vec<u8>>)> =
        ALL_KINDS.iter().map(|&k| (k, stepped_blobs(k))).collect();
    prop::cases(60, |rng| {
        let (kind, blobs) = &per_kind[rng.below(per_kind.len())];
        // Random chunk budget from pathological (1 byte) to generous,
        // random row split (0 = none, 4 = f32-aligned, or arbitrary).
        let budget = match rng.below(3) {
            0 => 1 + rng.below(7) as u64,
            1 => 8 + rng.below(64) as u64,
            _ => CHUNK_MAX_BYTES,
        };
        let row_bytes = [0u64, 4, 1 + rng.below(24) as u64][rng.below(3)];
        let mut jobs = jobs_for(blobs, budget, row_bytes);
        shuffle(&mut jobs, rng);
        let lens: Vec<u64> = blobs.iter().map(|b| b.len() as u64).collect();
        // Both trust models must reassemble identically.
        let mut asm = if rng.below(2) == 0 {
            ChunkAssembler::for_lens(&lens)
        } else {
            ChunkAssembler::for_unknown(blobs.len(), 1 << 20)
        };
        for &j in &jobs {
            feed(&mut asm, blobs, j).unwrap_or_else(|e| panic!("{kind:?}: {e}"));
        }
        assert!(asm.is_complete(), "{kind:?}");
        let got = asm.finish().unwrap_or_else(|e| panic!("{kind:?}: {e}"));
        assert_eq!(&got, blobs, "{kind:?} budget={budget} rows={row_bytes}");
        // The reassembled blobs load into a fresh optimizer and re-emit
        // byte-identically — the full pull-reconstruct-resume loop.
        let cfg = OptimConfig { lr: 1e-2, momentum: 0.9, ..Default::default() };
        let mut fresh = optim::build(*kind, &shapes(), &cfg);
        fresh.load_state_blobs(&got).unwrap_or_else(|e| panic!("{kind:?}: {e}"));
        assert_eq!(&fresh.state_blobs(), blobs, "{kind:?}");
    });
}

#[test]
fn prop_hostile_streams_are_rejected_with_typed_errors() {
    let blobs = stepped_blobs(OptKind::Smmf);
    let lens: Vec<u64> = blobs.iter().map(|b| b.len() as u64).collect();
    prop::cases(40, |rng| {
        let budget = 8 + rng.below(48) as u64;
        let mut jobs = jobs_for(&blobs, budget, 4);
        shuffle(&mut jobs, rng);

        // Duplicate: replaying any already-delivered chunk is refused.
        let mut asm = ChunkAssembler::for_lens(&lens);
        for &j in &jobs {
            feed(&mut asm, &blobs, j).unwrap();
        }
        let j = jobs[rng.below(jobs.len())];
        assert_eq!(
            asm.header(j.tensor, j.seq, j.total, j.start, j.count, j.len),
            Err(ChunkError::Duplicate { tensor_idx: j.tensor, seq: j.seq })
        );

        // Missing: drop one random chunk — finish() names it (or the
        // whole tensor, when the dropped chunk was the only header).
        let dropped = jobs[rng.below(jobs.len())];
        let mut asm = ChunkAssembler::for_lens(&lens);
        for &j in &jobs {
            if (j.tensor, j.seq) == (dropped.tensor, dropped.seq) {
                continue;
            }
            feed(&mut asm, &blobs, j).unwrap();
        }
        assert!(!asm.is_complete());
        let miss = asm.missing().expect("a dropped chunk must be reported missing");
        assert_eq!(miss.0, dropped.tensor);
        match asm.finish() {
            Err(ChunkError::Missing { tensor_idx, seq }) => {
                assert_eq!((tensor_idx, seq), (dropped.tensor, dropped.seq));
            }
            other => panic!("expected Missing, got {other:?}"),
        }

        // Overlap: shift a chunk so its span intersects a neighbor —
        // only meaningful for tensors with at least two data chunks.
        if let Some(j) = jobs.iter().find(|j| j.start > 0 && j.count > 0) {
            let mut asm = ChunkAssembler::for_lens(&lens);
            for &k in &jobs {
                if (k.tensor, k.seq) == (j.tensor, j.seq) {
                    continue;
                }
                feed(&mut asm, &blobs, k).unwrap();
            }
            assert_eq!(
                asm.header(j.tensor, j.seq, j.total, j.start - 1, j.count, j.len),
                Err(ChunkError::Overlap { tensor_idx: j.tensor, seq: j.seq })
            );
        }
    });
}
