#!/usr/bin/env bash
# Paper-scale streaming smoke: drive the chunked v4 wire path with
# inventories scaled past the live-frame cap and pin the streamed
# snapshot against the dense single-process reference.
#
#   bash rust/tests/stream_smoke.sh     # from the repo root
#   make stream-smoke                   # equivalent
#
# What runs:
#   1. the cross-protocol corruption battery (SMMFWIRE v4 / SMMFCELL /
#      SMMFCKPT under one deterministic driver) and the chunk-stream
#      property tests (every optimizer's state blobs under random
#      chunk budgets / row splits / arrival orders);
#   2. `repro loadgen --check` at 1x / 8x / 64x inventory scale — the
#      64x inventory's dense gradient set exceeds the 1 MiB live-frame
#      cap, so it only serves chunked; --check byte-compares the
#      server's streamed snapshot against the dense single-process
#      reference checkpoint (streamed == dense, bit for bit);
#   3. the three per-scale bench records (steps_per_s, bytes_per_step,
#      latency percentiles) merged into BENCH_server.json (or
#      $SMMF_SERVER_BENCH_JSON when set).
set -euo pipefail

cd "$(dirname "$0")/.."   # rust/

echo "== corruption battery (SMMFWIRE v4 / SMMFCELL / SMMFCKPT) =="
cargo test --release --test wire_corruption

echo "== chunk-stream properties (all optimizers, random streams) =="
cargo test --release --test chunk_stream

mkdir -p target/stream-smoke
for scale in 1 8 64; do
  if [ "$scale" = 1 ]; then
    model=synthetic:tiny_lm
  else
    model=synthetic:tiny_lm_x${scale}
  fi
  echo "== stream smoke (${scale}x inventory, loadgen --check, streamed-vs-dense snapshot) =="
  cargo run --release -- loadgen --model "$model" \
    --clients 2 --shards 2 --steps 8 \
    --snapshot "target/stream-smoke/snapshot_x${scale}.bin" --check \
    --bench-json "target/stream-smoke/BENCH_x${scale}.json"
done

# Merge the three single-record docs into one BENCH_server.json.
# Record objects never nest arrays, so the record payload is exactly
# what sits between `"records":[` and the closing `]}`.
rec() { sed -e 's/^.*"records":\[//' -e 's/\]}$//' "$1"; }
out="${SMMF_SERVER_BENCH_JSON:-../BENCH_server.json}"
printf '{"benchmark":"server_loadgen","records":[%s,%s,%s]}\n' \
  "$(rec target/stream-smoke/BENCH_x1.json)" \
  "$(rec target/stream-smoke/BENCH_x8.json)" \
  "$(rec target/stream-smoke/BENCH_x64.json)" > "$out"

echo "stream-smoke OK: 64x streamed snapshot byte-identical to the dense reference; 1x/8x/64x records -> $out"
