//! Replay/chaos suite for the bounded-staleness async mode: an async
//! run with an injected straggler is recorded to the commit log and
//! `replay_commit_log` re-executes it — on a *different* shard count —
//! to a byte-identical snapshot, at shards {1,2} × clients {2,4}. Plus
//! the staleness-window property test (typed `TooStale` on both the
//! push and pull sides) and the async member-table width check.
//!
//! Everything runs over real loopback TCP against the `tiny_lm`
//! inventory — no AOT artifacts, no PJRT.

use std::path::PathBuf;

use smmf_repro::coordinator::ExperimentConfig;
use smmf_repro::models::inventory_by_name;
use smmf_repro::optim::OptKind;
use smmf_repro::server::{
    replay_commit_log, run_loadgen, Client, CommitLog, LoadgenOptions, PullReply, PushOutcome,
    ServeOptions, Server,
};

fn tmp(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("smmf_replay_{tag}_{}.bin", std::process::id()))
}

fn test_config(kind: OptKind) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.optimizer = kind;
    cfg.optim = smmf_repro::optim::OptimConfig::paper_defaults(kind);
    cfg.optim.lr = 0.05;
    cfg.seed = 3;
    cfg
}

fn async_opts(shards: usize, clients: usize, staleness: u64, log: &PathBuf) -> ServeOptions {
    ServeOptions {
        addr: "127.0.0.1:0".into(),
        model: "synthetic:tiny_lm".into(),
        shards,
        clients,
        max_pending: 64,
        staleness,
        commit_log: Some(log.to_str().unwrap().into()),
        ..ServeOptions::default()
    }
}

fn zero_grads(shapes: &[Vec<usize>]) -> Vec<Vec<f32>> {
    shapes.iter().map(|s| vec![0.0f32; s.iter().product()]).collect()
}

/// The acceptance matrix: an async run with a straggler client logs
/// every commit, and replaying the log through the synchronous sharded
/// machinery — on the *other* shard count — reproduces the server's
/// snapshot byte for byte.
#[test]
fn async_straggler_log_replays_bit_identically_across_shards() {
    let steps = 10u64;
    let staleness = 3u64;
    let cfg = test_config(OptKind::Smmf);
    let shapes = inventory_by_name("tiny_lm").unwrap().shapes();
    for shards in [1usize, 2] {
        for clients in [2usize, 4] {
            let tag = format!("{shards}s_{clients}c");
            let log = tmp(&format!("{tag}_log"));
            let snap = tmp(&format!("{tag}_snap"));
            let replayed = tmp(&format!("{tag}_replay"));

            let server =
                Server::start(&cfg, &async_opts(shards, clients, staleness, &log)).unwrap();
            let addr = server.addr.to_string();
            run_loadgen(
                &addr,
                &shapes,
                cfg.seed,
                &LoadgenOptions {
                    clients,
                    steps,
                    slow_client_ms: 15.0,
                    ..LoadgenOptions::default()
                },
            )
            .unwrap();
            let mut ctl = Client::connect(&addr).unwrap();
            let stats = ctl.stats().unwrap();
            ctl.snapshot(snap.to_str().unwrap()).unwrap();
            ctl.shutdown().unwrap();
            server.wait().unwrap();

            assert_eq!(stats.staleness, staleness, "{tag}");
            assert!(stats.step >= steps, "{tag}: {} commits for {steps} pushes", stats.step);

            // The log's own invariants: one record per applied step,
            // every contributor inside the advertised window.
            let recorded = CommitLog::load(&log).unwrap();
            assert_eq!(recorded.header.staleness, staleness, "{tag}");
            assert_eq!(recorded.commits.len() as u64, stats.step, "{tag}");
            assert!(
                recorded.max_lag() <= staleness,
                "{tag}: observed lag {} exceeds the window {staleness}",
                recorded.max_lag()
            );

            // Replay on the *other* shard count: commit bits must not
            // depend on the partitioning.
            let report =
                replay_commit_log(&cfg, &log, 3 - shards, &replayed).unwrap();
            assert_eq!(report.commits, stats.step, "{tag}");
            assert_eq!(report.final_step, stats.step, "{tag}");

            let got = std::fs::read(&replayed).unwrap();
            let want = std::fs::read(&snap).unwrap();
            assert_eq!(got.len() as u64, report.snapshot_bytes, "{tag}");
            assert!(got == want, "{tag}: replayed snapshot differs from the server's");

            for p in [&log, &snap, &replayed] {
                std::fs::remove_file(p).ok();
            }
        }
    }
}

/// The staleness window as a property: with window S, a push based on
/// parameters older than `applied - S` gets the typed `TooStale` reply
/// (checked *before* payload validation), a pull floor above the
/// applied step gets the pull-side `TooStale`, a reachable floor is
/// honored, and a base step from the future is rejected outright.
#[test]
fn staleness_window_bounds_push_and_pull() {
    let staleness = 2u64;
    let cfg = test_config(OptKind::Smmf);
    let shapes = inventory_by_name("tiny_lm").unwrap().shapes();
    let log = tmp("window_log");

    let server = Server::start(&cfg, &async_opts(1, 2, staleness, &log)).unwrap();
    let addr = server.addr.to_string();

    // Client 0 sprints ahead: four committed pushes, each based on the
    // step the previous one produced.
    let mut fast = Client::connect(&addr).unwrap();
    let mut base = 0u64;
    for _ in 0..4 {
        match fast.push_grad(0, 1, base + 1, base, zero_grads(&shapes)).unwrap() {
            PushOutcome::Applied(step) => base = step,
            other => panic!("fast client push answered {other:?}"),
        }
    }
    assert_eq!(base, 4, "four commits applied");

    // Client 1 never pulled: base_step 0 is below required = 4 - S = 2.
    // Empty grads prove the window check runs before shape validation.
    let mut lag = Client::connect(&addr).unwrap();
    let out = lag.push_grad(1, 1, 1, 0, vec![]).unwrap();
    assert_eq!(out, PushOutcome::TooStale { applied: 4, required: 2 });

    // Pull side: an unreachable floor is refused with the same shape...
    let reply = lag.pull_params_at_least(99).unwrap();
    assert_eq!(reply, PullReply::TooStale { applied: 4, required: 99 });
    // ...and a reachable one hands back the applied step.
    match lag.pull_params_at_least(3).unwrap() {
        PullReply::Params { step, tensors } => {
            assert_eq!(step, 4);
            assert_eq!(tensors.len(), shapes.len());
        }
        other => panic!("reachable pull floor answered {other:?}"),
    }

    // A base step the server has not produced yet is nonsense, not
    // merely stale: rejected outright.
    match lag.push_grad(1, 1, 10, 9, zero_grads(&shapes)).unwrap() {
        PushOutcome::Rejected(_) => {}
        other => panic!("future base_step answered {other:?}"),
    }

    // A lagging-but-in-window push lands: base 3 with applied = 4.
    match lag.push_grad(1, 1, 5, 3, zero_grads(&shapes)).unwrap() {
        PushOutcome::Applied(step) => assert_eq!(step, 5),
        other => panic!("in-window push answered {other:?}"),
    }

    Client::connect(&addr).unwrap().shutdown().unwrap();
    server.wait().unwrap();
    std::fs::remove_file(&log).ok();
}

/// Async mode relaxes the loadgen width check from "exactly the
/// barrier" to "at most the member table": driving fewer clients than
/// members works (no barrier to starve), driving more fails fast with
/// a clear message instead of a hail of non-member rejections.
#[test]
fn async_loadgen_width_is_bounded_by_the_member_table() {
    let cfg = test_config(OptKind::Smmf);
    let shapes = inventory_by_name("tiny_lm").unwrap().shapes();
    let log = tmp("width_log");

    let server = Server::start(&cfg, &async_opts(1, 2, 1, &log)).unwrap();
    let addr = server.addr.to_string();

    let err = run_loadgen(
        &addr,
        &shapes,
        cfg.seed,
        &LoadgenOptions { clients: 4, steps: 2, ..LoadgenOptions::default() },
    )
    .unwrap_err();
    assert!(err.to_string().contains("member"), "{err:#}");

    run_loadgen(
        &addr,
        &shapes,
        cfg.seed,
        &LoadgenOptions { clients: 1, steps: 3, ..LoadgenOptions::default() },
    )
    .unwrap();
    let stats = Client::connect(&addr).unwrap().stats().unwrap();
    assert!(stats.step >= 3, "{}", stats.step);

    Client::connect(&addr).unwrap().shutdown().unwrap();
    server.wait().unwrap();
    std::fs::remove_file(&log).ok();
}
