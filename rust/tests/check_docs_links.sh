#!/usr/bin/env bash
# Docs link/anchor checker, run by `make docs-check`.
#
# 1. Every relative markdown link in README.md and docs/*.md must point
#    at a file that exists (anchors after '#' are stripped; http(s) and
#    mailto links are skipped).
# 2. Every `path:line` code anchor in docs/ARCHITECTURE.md (backticked
#    `rust/...:N` references) must name an existing file with at least N
#    lines — so the module guide cannot silently rot as code moves.
set -euo pipefail

cd "$(dirname "$0")/../.."   # repo root

fail=0

for f in README.md docs/*.md; do
  while IFS= read -r link; do
    [ -z "$link" ] && continue
    case "$link" in
      http://*|https://*|mailto:*|'#'*) continue ;;
    esac
    target="${link%%#*}"
    [ -z "$target" ] && continue
    base="$(dirname "$f")"
    if [ ! -e "$target" ] && [ ! -e "$base/$target" ]; then
      echo "BROKEN LINK: $f -> $link"
      fail=1
    fi
  done < <(grep -oE '\]\([^)]+\)' "$f" 2>/dev/null | sed -E 's/^\]\(//; s/\)$//')
done

if [ -f docs/ARCHITECTURE.md ]; then
  while IFS=: read -r path line; do
    [ -z "$path" ] && continue
    if [ ! -f "$path" ]; then
      echo "BROKEN ANCHOR: docs/ARCHITECTURE.md -> $path:$line (no such file)"
      fail=1
    elif [ "$(wc -l < "$path")" -lt "$line" ]; then
      echo "BROKEN ANCHOR: docs/ARCHITECTURE.md -> $path:$line (file has only $(wc -l < "$path") lines)"
      fail=1
    fi
  done < <(grep -oE '`(rust|python|docs|examples)/[A-Za-z0-9_./-]+:[0-9]+' docs/ARCHITECTURE.md | tr -d '`')
fi

if [ "$fail" -ne 0 ]; then
  echo "docs link check FAILED"
  exit 1
fi
echo "docs link check OK"
