//! Integration tests over the full stack: AOT artifacts -> PJRT runtime
//! -> trainer -> optimizers. These need `make artifacts` to have run;
//! they self-skip (with a notice) when the artifacts are absent so that
//! pure-Rust CI still passes.

use smmf_repro::coordinator::experiments::{run_experiment, BatchSource};
use smmf_repro::coordinator::ExperimentConfig;
use smmf_repro::optim::OptKind;
use smmf_repro::runtime::Runtime;
use smmf_repro::train::{FusedSmmfStep, TrainGraph, Trainer};

fn runtime() -> Option<Runtime> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping integration test: artifacts not built");
        return None;
    }
    Some(Runtime::open(dir).expect("runtime"))
}

#[test]
fn mlp_loss_decreases_under_every_optimizer() {
    let Some(rt) = runtime() else { return };
    for kind in OptKind::all() {
        let mut cfg = ExperimentConfig::default();
        cfg.artifact = "mlp_grads".into();
        cfg.optimizer = kind;
        cfg.optim = smmf_repro::optim::OptimConfig::paper_defaults(kind);
        cfg.optim.relative_step = false;
        cfg.steps = 40;
        cfg.name = format!("it_mlp/{}", kind.name());
        cfg.out_dir = std::env::temp_dir().join("smmf_it_runs").to_string_lossy().into_owned();
        let s = run_experiment(&rt, &cfg).expect(kind.name());
        assert!(
            s.final_loss < s.first_loss * 0.9,
            "{}: {} -> {}",
            kind.name(),
            s.first_loss,
            s.final_loss
        );
    }
}

#[test]
fn fused_pallas_step_matches_rust_smmf_trajectory() {
    // The compiled (Pallas-kernel) SMMF train step and the Rust fused
    // optimizer must produce the same loss trajectory on the same data:
    // L1 == L3 semantics across the whole stack.
    let Some(rt) = runtime() else { return };
    let mut fused = FusedSmmfStep::load(&rt, "mlp_smmf_step", 0).unwrap();

    let graph = TrainGraph::load(&rt, "mlp_grads").unwrap();
    let shapes = graph.param_shapes();
    // Match the hyper-parameters baked into the fused artifact.
    let hyper = fused.spec().meta.clone();
    let mut cfg = smmf_repro::optim::OptimConfig::paper_defaults(OptKind::Smmf);
    cfg.lr = *hyper.get("lr").unwrap_or(&1e-3) as f32;
    cfg.decay_rate = *hyper.get("decay_rate").unwrap_or(&-0.8) as f32;
    cfg.weight_decay = 0.0;
    let opt = smmf_repro::optim::build(OptKind::Smmf, &shapes, &cfg);
    let mut trainer = Trainer::new(
        graph,
        opt,
        0, // same seed -> same init as the fused path
        cfg.lr,
        smmf_repro::optim::schedule::LrSchedule::Constant,
    );

    let mut src_a = BatchSource::for_spec(fused.spec(), 7).unwrap();
    let mut src_b = BatchSource::for_spec(trainer.graph.spec(), 7).unwrap();
    for step in 0..8 {
        let (ba, bb) = (src_a.next().unwrap(), src_b.next().unwrap());
        let la = fused.train_step(&ba).unwrap();
        let lb = trainer.train_step(&bb).unwrap();
        assert!(
            (la - lb).abs() < 2e-3 * lb.abs().max(1.0),
            "step {step}: fused {la} vs rust {lb}"
        );
    }
}

#[test]
fn lm_tiny_trains_on_real_corpus() {
    let Some(rt) = runtime() else { return };
    let mut cfg = ExperimentConfig::default();
    cfg.artifact = "lm_tiny_grads".into();
    cfg.optimizer = OptKind::Smmf;
    cfg.optim.decay_rate = -0.8;
    cfg.steps = 30;
    cfg.name = "it_lm/smmf".into();
    cfg.out_dir = std::env::temp_dir().join("smmf_it_runs").to_string_lossy().into_owned();
    let s = run_experiment(&rt, &cfg).unwrap();
    assert!(s.final_loss < s.first_loss, "{} -> {}", s.first_loss, s.final_loss);
    // char-LM over 96 symbols starts near ln(96) ≈ 4.56
    assert!((3.5..5.0).contains(&s.first_loss), "{}", s.first_loss);
}

#[test]
fn lora_adapters_train_with_frozen_base() {
    let Some(rt) = runtime() else { return };
    let mut cfg = ExperimentConfig::default();
    cfg.artifact = "lora_tiny_grads".into();
    cfg.optimizer = OptKind::Smmf;
    cfg.optim.lr = 1e-3;
    cfg.optim.decay_rate = -0.8;
    cfg.steps = 25;
    cfg.name = "it_lora/smmf".into();
    cfg.out_dir = std::env::temp_dir().join("smmf_it_runs").to_string_lossy().into_owned();
    let s = run_experiment(&rt, &cfg).unwrap();
    assert!(s.final_loss < s.first_loss, "{} -> {}", s.first_loss, s.final_loss);
}

#[test]
fn smmf_tensor_artifact_matches_rust_hot_path() {
    // The bare Pallas per-tensor kernel artifact vs the Rust fused
    // implementation on identical inputs: numerical agreement at the
    // kernel level, through the compiled runtime.
    let Some(rt) = runtime() else { return };
    let graph = rt.load("smmf_tensor_1024x1024").unwrap();
    let (n, m) = (1024usize, 1024usize);
    let mut rng = smmf_repro::util::rng::Pcg32::new(3);
    let g: Vec<f32> = (0..n * m).map(|_| rng.normal() * 0.02).collect();
    let (beta_m, beta_v, eps) = (0.9f32, 0.0f32, 1e-8f32);

    let outs = graph
        .run(&[
            smmf_repro::runtime::lit_f32(&[n, m], &g).unwrap(),
            smmf_repro::runtime::lit_f32(&[n], &vec![0.0; n]).unwrap(),
            smmf_repro::runtime::lit_f32(&[m], &vec![0.0; m]).unwrap(),
            smmf_repro::runtime::lit_pred(&[n, m], &vec![false; n * m]).unwrap(),
            smmf_repro::runtime::lit_f32(&[n], &vec![0.0; n]).unwrap(),
            smmf_repro::runtime::lit_f32(&[m], &vec![0.0; m]).unwrap(),
            smmf_repro::runtime::lit_scalar_f32(beta_m),
            smmf_repro::runtime::lit_scalar_f32(beta_v),
            smmf_repro::runtime::lit_scalar_f32(eps),
        ])
        .unwrap();
    let u_pallas = smmf_repro::runtime::lit_to_vec_f32(&outs[0]).unwrap();

    // Rust fused path: one step from zero state with lr folded out.
    let mut cfg = smmf_repro::optim::OptimConfig::paper_defaults(OptKind::Smmf);
    cfg.lr = 1.0;
    cfg.growth_rate = 1.0; // beta_m stays 0.9 at t=1
    cfg.decay_rate = -1.0; // beta_v = 1 - 1 = 0 at t=1
    cfg.eps1 = eps;
    let mut opt = smmf_repro::optim::Smmf::new(&[vec![n, m]], &cfg);
    let mut params = vec![smmf_repro::tensor::Tensor::zeros(&[n, m])];
    let grads = vec![smmf_repro::tensor::Tensor::from_vec(&[n, m], g)];
    use smmf_repro::optim::Optimizer;
    opt.step(&mut params, &grads);
    // params = -lr * U  =>  U = -params
    for (a, b) in u_pallas.iter().zip(params[0].data()) {
        assert!((a + b).abs() <= 1e-5 + 1e-4 * a.abs(), "pallas {a} vs rust {}", -b);
    }
}
