//! Dispatcher-side tests that need no live worker: the `[suite]
//! workers` spec grammar end-to-end through `SuiteConfig`, the wire
//! config rendering for expanded cells, dead-address failure isolation
//! (`FAILED` markers + clean local retry), and mixed local+remote
//! scheduling where the remote half never answers.

use std::path::PathBuf;

use smmf_repro::coordinator::config::{ExperimentConfig, SuiteConfig, WorkerSpec};
use smmf_repro::coordinator::suite::{run_suite, CellStatus, SuiteOptions};

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("smmf_rdisp_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

const SMOKE: &str = r#"
[suite]
name = "smoke"
seeds = [0, 1]

[optimizer]
lr = 0.05

[train]
steps = 8
log_every = 4

[[suite.run]]
optimizers = ["adam", "smmf"]
models = ["synthetic:tiny_lm"]
"#;

/// An address nothing listens on: bind an ephemeral port, then drop the
/// listener — connects to it are refused immediately.
fn dead_addr() -> String {
    let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = l.local_addr().unwrap().to_string();
    drop(l);
    addr
}

#[test]
fn suite_toml_carries_remote_worker_specs() {
    let full = r#"
[suite]
name = "smoke"
workers = "local:2,remote:127.0.0.1:7131,127.0.0.1:7132"

[[suite.run]]
optimizers = ["adam"]
models = ["synthetic:tiny_lm"]
"#;
    let cfg = SuiteConfig::parse(full, "x").unwrap();
    assert_eq!(
        cfg.workers,
        WorkerSpec {
            local: 2,
            remote: vec!["127.0.0.1:7131".into(), "127.0.0.1:7132".into()]
        }
    );
    assert!(!cfg.workers.is_local_only());
    assert_eq!(cfg.workers.describe(), "2 remote + 2 local worker(s)");

    // Plain integers stay the local thread-pool spelling.
    let plain = SuiteConfig::parse(SMOKE, "x").unwrap();
    assert_eq!(plain.workers, WorkerSpec::local(1));
    assert!(plain.workers.is_local_only());
}

#[test]
fn every_expanded_cell_renders_to_wire_toml_losslessly() {
    // The dispatcher ships `cell.cfg.to_toml()`; the worker rebuilds via
    // `from_toml_str`. Every cell of a realistic sweep must survive the
    // round trip *exactly* — this is what makes remote execution
    // semantically identical to local.
    let text = r#"
[suite]
name = "wire"
seeds = [0, 7]

[optimizer]
lr = 1e-3
weight_decay = 0.01

[schedule]
kind = "linear"
warmup = 5
total = 50

[[optimizer.group]]
name = "no_decay"
match_role = ["bias", "norm"]
weight_decay = 0.0

[train]
steps = 20
log_every = 5

[[suite.run]]
optimizers = ["adam", "smmf", "adafactor", "came", "sm3", "sgd"]
models = ["synthetic:tiny_lm"]
"#;
    let cfg = SuiteConfig::parse(text, "x").unwrap();
    let cells = cfg.expand().unwrap();
    assert_eq!(cells.len(), 12, "6 optimizers × 2 seeds");
    for cell in &cells {
        let wire = cell.cfg.to_toml().unwrap_or_else(|e| panic!("{}: {e:#}", cell.run));
        let back = ExperimentConfig::from_toml_str(&wire)
            .unwrap_or_else(|e| panic!("{}: {e:#}\n{wire}", cell.run));
        assert_eq!(back, cell.cfg, "{} drifts through the wire rendering:\n{wire}", cell.run);
    }
}

#[test]
fn all_workers_dead_fails_cells_with_markers_then_local_retry_clears_them() {
    let tmp = tmp_dir("dead");
    let mut cfg = SuiteConfig::parse(SMOKE, "x").unwrap();
    cfg.out_dir = tmp.to_str().unwrap().to_string();

    // Two refused addresses, short lease: every cell must fail fast and
    // loudly instead of hanging the suite.
    let opts = SuiteOptions {
        workers: Some(WorkerSpec { local: 0, remote: vec![dead_addr(), dead_addr()] }),
        lease_timeout_ms: 250,
        ..SuiteOptions::default()
    };
    let out = run_suite(&cfg, &opts).unwrap();
    assert_eq!(out.counts(), (0, 0, 4), "all cells failed, none hung");
    for (cell, status) in &out.cells {
        match status {
            CellStatus::Failed(note) => {
                assert!(note.contains("no live workers"), "{}: {note}", cell.run)
            }
            other => panic!("{}: expected Failed, got {other:?}", cell.run),
        }
        assert!(
            out.suite_dir.join(&cell.run).join("FAILED").exists(),
            "{} needs its FAILED marker for the retry path",
            cell.run
        );
    }

    // FAILED markers make the next (local) invocation retry exactly
    // these cells — the cross-backend recovery story.
    let local = SuiteOptions::default();
    let out2 = run_suite(&cfg, &local).unwrap();
    assert_eq!(out2.counts(), (4, 0, 0), "local retry trains everything");
    for (cell, _) in &out2.cells {
        assert!(!out2.suite_dir.join(&cell.run).join("FAILED").exists(), "{}", cell.run);
        assert!(out2.suite_dir.join(&cell.run).join("summary.json").exists(), "{}", cell.run);
    }
    let _ = std::fs::remove_dir_all(tmp);
}

#[test]
fn local_lanes_carry_a_suite_whose_remote_half_is_dead() {
    let tmp = tmp_dir("mixed_dead");
    let mut cfg = SuiteConfig::parse(SMOKE, "x").unwrap();
    cfg.out_dir = tmp.to_str().unwrap().to_string();

    // One dead remote + one local lane: the local lane must absorb the
    // whole suite once the remote lease expires.
    let opts = SuiteOptions {
        workers: Some(WorkerSpec { local: 1, remote: vec![dead_addr()] }),
        lease_timeout_ms: 250,
        ..SuiteOptions::default()
    };
    let out = run_suite(&cfg, &opts).unwrap();
    assert_eq!(out.counts(), (4, 0, 0), "local lane completed every cell");
    // Statuses stay in expansion order regardless of scheduling.
    let runs: Vec<&str> = out.cells.iter().map(|(c, _)| c.run.as_str()).collect();
    assert_eq!(
        runs,
        vec!["tiny_lm-adam-s0", "tiny_lm-adam-s1", "tiny_lm-smmf-s0", "tiny_lm-smmf-s1"]
    );
    let _ = std::fs::remove_dir_all(tmp);
}

#[test]
fn dispatch_prepass_honors_the_reentry_cache() {
    let tmp = tmp_dir("prepass");
    let mut cfg = SuiteConfig::parse(SMOKE, "x").unwrap();
    cfg.out_dir = tmp.to_str().unwrap().to_string();

    // Seed the cache with a local run.
    let out = run_suite(&cfg, &SuiteOptions::default()).unwrap();
    assert_eq!(out.counts(), (4, 0, 0));

    // A remote invocation over the same dir must skip every cell in the
    // pre-pass — no worker is ever contacted, so even a dead address
    // finishes instantly with all-Skipped.
    let opts = SuiteOptions {
        workers: Some(WorkerSpec { local: 0, remote: vec![dead_addr()] }),
        lease_timeout_ms: 250,
        ..SuiteOptions::default()
    };
    let out2 = run_suite(&cfg, &opts).unwrap();
    assert_eq!(out2.counts(), (0, 4, 0), "re-entry cache crosses backends");
    let _ = std::fs::remove_dir_all(tmp);
}

#[test]
fn bad_worker_specs_are_rejected_at_the_cli_grammar() {
    for bad in [
        "",
        "0",
        "-3",
        "local:0",
        "local:x",
        "remote:nocolon",
        "remote:a:1,a:1",
        "local:1,local:2",
        "many",
    ] {
        assert!(WorkerSpec::parse(bad).is_err(), "accepted {bad:?}");
    }
    let spec = WorkerSpec::parse("remote:127.0.0.1:7131,127.0.0.1:7132,local:3").unwrap();
    assert_eq!(spec.local, 3);
    assert_eq!(spec.remote.len(), 2);
    assert_eq!(WorkerSpec::parse("4").unwrap(), WorkerSpec::local(4));
}
