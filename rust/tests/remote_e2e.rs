//! Distributed-suite end-to-end tests: real `WorkerServer` daemons on
//! loopback sockets driven through `run_suite`'s remote backend.
//!
//! The headline properties pinned here (the PR's acceptance criteria):
//!
//! * A suite dispatched to two remote workers completes every cell,
//!   commits statuses in expansion order, and — via the cross-backend
//!   re-entry cache — re-renders `docs/RESULTS.md` / `BENCH_suite.json`
//!   **byte-identically** under the local thread-pool backend.
//! * A worker that goes silent mid-suite (the `crash_after_accepts`
//!   chaos knob) has its cells re-dispatched to the survivor and the
//!   suite still completes with the same reports.
//! * A second invocation skips every completed cell (all-`Skipped`).

use std::path::{Path, PathBuf};

use smmf_repro::coordinator::config::{SuiteConfig, WorkerSpec};
use smmf_repro::coordinator::remote::protocol::CellMsg;
use smmf_repro::coordinator::remote::{CellClient, WorkerOptions, WorkerServer};
use smmf_repro::coordinator::report;
use smmf_repro::coordinator::suite::{run_suite, CellStatus, SuiteOptions};

/// A *relative* scratch dir (under `target/`): the worker daemon refuses
/// absolute `out_dir`s as parent-escape protection, and coordinator +
/// in-process workers share this test's cwd, so relative paths mean both
/// sides read and write the same cell directories.
fn tmp_dir(tag: &str) -> PathBuf {
    let dir = PathBuf::from(format!("target/tmp/smmf_re2e_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// 2 optimizers × 3 seeds on the artifact-free synthetic workload —
/// enough cells that both workers stay busy and a mid-suite death
/// leaves work to re-dispatch.
fn smoke_suite(out_dir: &Path) -> SuiteConfig {
    let mut cfg = SuiteConfig::parse(
        r#"
[suite]
name = "smoke"
seeds = [0, 1, 2]

[optimizer]
lr = 0.05

[train]
steps = 8
log_every = 4

[[suite.run]]
optimizers = ["adam", "smmf"]
models = ["synthetic:tiny_lm"]
"#,
        "x",
    )
    .unwrap();
    cfg.out_dir = out_dir.to_str().unwrap().to_string();
    cfg
}

fn start_worker(capacity: usize, crash_after: u64) -> WorkerServer {
    WorkerServer::start(&WorkerOptions {
        capacity,
        crash_after_accepts: crash_after,
        io_timeout: Some(std::time::Duration::from_secs(5)),
        ..WorkerOptions::default()
    })
    .unwrap()
}

fn remote_spec(workers: &[&WorkerServer]) -> WorkerSpec {
    WorkerSpec { local: 0, remote: workers.iter().map(|w| w.addr.to_string()).collect() }
}

/// Render both report artifacts from a suite dir and return their bytes.
fn report_bytes(tag: &str, suite_dir: &Path, tmp: &Path) -> (Vec<u8>, Vec<u8>) {
    let docs = tmp.join(format!("RESULTS.{tag}.md"));
    let bench = tmp.join(format!("BENCH.{tag}.json"));
    report::write_report("smoke", suite_dir, &docs, &bench).unwrap();
    (std::fs::read(docs).unwrap(), std::fs::read(bench).unwrap())
}

#[test]
fn worker_daemon_runs_a_cell_end_to_end() {
    let tmp = tmp_dir("daemon");
    let cfg = smoke_suite(&tmp);
    let cells = cfg.expand().unwrap();
    let cell = &cells[0];

    let server = start_worker(1, 0);
    let mut c =
        CellClient::connect(&server.addr.to_string(), Some(std::time::Duration::from_secs(5)))
            .unwrap();
    let wire = cell.cfg.to_toml().unwrap();
    const NONCE: u64 = 0xA11C_E000;
    let poll_done = |c: &mut CellClient, nonce: u64, job: u64| {
        // Poll to completion (tiny cell: milliseconds).
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
        loop {
            match c.poll(nonce, job).unwrap() {
                CellMsg::Running { .. } => {
                    assert!(std::time::Instant::now() < deadline, "cell never finished");
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
                CellMsg::Done { .. } => break,
                other => panic!("expected Running/Done, got {}", other.name()),
            }
        }
    };
    match c.submit(NONCE, 0, &cell.run, &cell.model, &wire).unwrap() {
        CellMsg::Accepted { job: 0 } => {}
        other => panic!("expected Accepted, got {}", other.name()),
    }
    poll_done(&mut c, NONCE, 0);
    assert!(
        tmp.join("smoke").join(&cell.run).join("summary.json").exists(),
        "worker leaves the standard artifacts"
    );
    // Idempotent re-submit of a finished job answers Done immediately —
    // but only under the *same* suite-run nonce.
    match c.submit(NONCE, 0, &cell.run, &cell.model, &wire).unwrap() {
        CellMsg::Done { job: 0 } => {}
        other => panic!("expected Done on re-submit, got {}", other.name()),
    }
    // The same job id under a fresh nonce is a new suite run (the
    // `--force` / second-suite case against a persistent daemon): the
    // worker must execute it again, never answer the stale verdict.
    match c.submit(NONCE + 1, 0, &cell.run, &cell.model, &wire).unwrap() {
        CellMsg::Accepted { job: 0 } => {}
        other => panic!("expected Accepted under a fresh nonce, got {}", other.name()),
    }
    poll_done(&mut c, NONCE + 1, 0);
    // And the old nonce's verdict was pruned by the new run's submit.
    match c.poll(NONCE, 0).unwrap() {
        CellMsg::Err { msg } => assert!(msg.contains("unknown job"), "{msg}"),
        other => panic!("expected Err for the pruned job, got {}", other.name()),
    }
    // A hostile out_dir is refused before any filesystem traffic.
    let evil = wire.replace(
        &format!("out_dir = \"{}\"", cfg.out_dir),
        "out_dir = \"../../etc\"",
    );
    assert_ne!(evil, wire, "fixture must actually rewrite out_dir");
    match c.submit(NONCE + 1, 1, &cell.run, &cell.model, &evil).unwrap() {
        CellMsg::Err { msg } => assert!(msg.contains("refusing"), "{msg}"),
        other => panic!("expected Err for hostile path, got {}", other.name()),
    }
    c.shutdown().unwrap();
    let stats = server.wait();
    assert_eq!((stats.accepted, stats.done, stats.failed), (2, 2, 0));
    let _ = std::fs::remove_dir_all(tmp);
}

#[test]
fn two_workers_run_the_suite_and_reports_match_the_local_backend_bytewise() {
    let tmp = tmp_dir("two");
    let cfg = smoke_suite(&tmp);
    let w1 = start_worker(1, 0);
    let w2 = start_worker(1, 0);

    let opts = SuiteOptions {
        workers: Some(remote_spec(&[&w1, &w2])),
        lease_timeout_ms: 5_000,
        ..SuiteOptions::default()
    };
    let out = run_suite(&cfg, &opts).unwrap();
    assert_eq!(out.counts(), (6, 0, 0), "all cells ran remotely");
    // Statuses commit in expansion order no matter which worker (or in
    // which order) the cells finished.
    let runs: Vec<&str> = out.cells.iter().map(|(c, _)| c.run.as_str()).collect();
    assert_eq!(
        runs,
        vec![
            "tiny_lm-adam-s0",
            "tiny_lm-adam-s1",
            "tiny_lm-adam-s2",
            "tiny_lm-smmf-s0",
            "tiny_lm-smmf-s1",
            "tiny_lm-smmf-s2"
        ]
    );
    // Both workers did real work (the dispatcher actually fanned out).
    let (s1, s2) = (w1.stats(), w2.stats());
    assert_eq!(s1.done + s2.done, 6, "{s1:?} {s2:?}");
    assert!(s1.done >= 1 && s2.done >= 1, "one worker hogged the suite: {s1:?} {s2:?}");

    let (docs_remote, bench_remote) = report_bytes("remote", &out.suite_dir, &tmp);

    // Second invocation, *local thread-pool backend*, same suite dir:
    // the re-entry cache skips every completed cell (acceptance
    // criterion) and the re-rendered reports are byte-identical — the
    // backend is invisible in the artifacts.
    let local_opts = SuiteOptions::default();
    let out2 = run_suite(&cfg, &local_opts).unwrap();
    assert_eq!(out2.counts(), (0, 6, 0), "cross-backend re-entry: all cached");
    let (docs_local, bench_local) = report_bytes("local", &out2.suite_dir, &tmp);
    assert_eq!(docs_remote, docs_local, "docs/RESULTS.md bytes differ across backends");
    assert_eq!(bench_remote, bench_local, "BENCH_suite.json bytes differ across backends");

    // And a third run over the remote backend is also all-Skipped.
    let out3 = run_suite(&cfg, &opts).unwrap();
    assert_eq!(out3.counts(), (0, 6, 0));
    assert!(out3.cells.iter().all(|(_, s)| *s == CellStatus::Skipped));

    for c in [&w1, &w2] {
        CellClient::connect(&c.addr.to_string(), None).unwrap().shutdown().unwrap();
    }
    w1.wait();
    w2.wait();
    let _ = std::fs::remove_dir_all(tmp);
}

/// The persistent-daemon regression: job ids are suite expansion
/// indices, so a second dispatch to a worker that served a previous run
/// reuses them. A `--force` re-run deletes every `summary.json` first —
/// if the worker answered those re-used ids from its old job table, the
/// dispatcher would record cells as Ran without any execution and the
/// report would read from deleted files. The per-run nonce makes the
/// second dispatch fresh work.
#[test]
fn force_rerun_against_persistent_workers_retrains_every_cell() {
    let tmp = tmp_dir("force");
    let cfg = smoke_suite(&tmp);
    let w1 = start_worker(1, 0);
    let w2 = start_worker(1, 0);

    let opts = SuiteOptions {
        workers: Some(remote_spec(&[&w1, &w2])),
        lease_timeout_ms: 5_000,
        ..SuiteOptions::default()
    };
    let out = run_suite(&cfg, &opts).unwrap();
    assert_eq!(out.counts(), (6, 0, 0));
    let first_done = w1.stats().done + w2.stats().done;
    assert_eq!(first_done, 6);

    // Same daemons, same expansion indices, --force: every cell must
    // actually train again on the workers.
    let force_opts = SuiteOptions { force: true, ..opts.clone() };
    let out2 = run_suite(&cfg, &force_opts).unwrap();
    assert_eq!(out2.counts(), (6, 0, 0), "force re-run executes every cell");
    assert_eq!(
        w1.stats().done + w2.stats().done,
        12,
        "workers re-trained the cells instead of replaying stale verdicts"
    );
    for (cell, _) in &out2.cells {
        assert!(
            out2.suite_dir.join(&cell.run).join("summary.json").exists(),
            "{}: forced re-run must leave a fresh summary",
            cell.run
        );
    }

    for w in [&w1, &w2] {
        CellClient::connect(&w.addr.to_string(), None).unwrap().shutdown().unwrap();
    }
    w1.wait();
    w2.wait();
    let _ = std::fs::remove_dir_all(tmp);
}

#[test]
fn mid_suite_worker_death_redispatches_to_the_survivor() {
    let tmp = tmp_dir("chaos");
    let cfg = smoke_suite(&tmp);
    let healthy = start_worker(1, 0);
    // capacity 2 so the doomed worker holds one accepted-and-running
    // cell *and* one accepted-then-stranded cell when the chaos latch
    // fires on its second accept — exercising both the lease-expiry
    // requeue and the completed-before-death cache recheck.
    let doomed = start_worker(2, 2);

    let opts = SuiteOptions {
        workers: Some(remote_spec(&[&doomed, &healthy])),
        lease_timeout_ms: 400,
        ..SuiteOptions::default()
    };
    let out = run_suite(&cfg, &opts).unwrap();
    let (ran, skipped, failed) = out.counts();
    assert_eq!(failed, 0, "death must re-dispatch, not fail cells");
    assert_eq!(skipped, 0);
    assert_eq!(ran, 6, "every cell completes despite the mid-suite crash");
    // The survivor picked up real work.
    assert!(healthy.stats().done >= 4, "survivor stats: {:?}", healthy.stats());

    let (docs_chaos, bench_chaos) = report_bytes("chaos", &out.suite_dir, &tmp);

    // Reports re-rendered under the local backend are byte-identical —
    // worker death and re-dispatch left no trace in the artifacts.
    let out2 = run_suite(&cfg, &SuiteOptions::default()).unwrap();
    assert_eq!(out2.counts(), (0, 6, 0));
    let (docs_local, bench_local) = report_bytes("chaos_local", &out2.suite_dir, &tmp);
    assert_eq!(docs_chaos, docs_local, "chaos run's docs bytes differ from local");
    assert_eq!(bench_chaos, bench_local, "chaos run's bench bytes differ from local");

    CellClient::connect(&healthy.addr.to_string(), None).unwrap().shutdown().unwrap();
    healthy.wait();
    // `doomed` crashed silently; its handle just drops (Drop sets the
    // shutdown flag for the already-dead accept loop).
    drop(doomed);
    let _ = std::fs::remove_dir_all(tmp);
}

#[test]
fn capacity_one_worker_absorbs_busy_bounces() {
    let tmp = tmp_dir("busy");
    let cfg = smoke_suite(&tmp);
    let w = start_worker(1, 0);
    let opts = SuiteOptions {
        workers: Some(remote_spec(&[&w])),
        lease_timeout_ms: 5_000,
        ..SuiteOptions::default()
    };
    let out = run_suite(&cfg, &opts).unwrap();
    assert_eq!(out.counts(), (6, 0, 0), "serial worker still completes the suite");
    CellClient::connect(&w.addr.to_string(), None).unwrap().shutdown().unwrap();
    let stats = w.wait();
    assert_eq!(stats.done, 6);
    let _ = std::fs::remove_dir_all(tmp);
}

#[test]
fn mixed_local_and_remote_lanes_share_the_queue() {
    let tmp = tmp_dir("mixed");
    // Heavier cells than the smoke suite: each must run long enough that
    // the dispatcher's first dial + submit lands while the local lane is
    // still training its first pop — otherwise the split assertion races.
    let mut cfg = SuiteConfig::parse(
        r#"
[suite]
name = "smoke"
seeds = [0, 1]

[optimizer]
lr = 0.05

[train]
steps = 400
log_every = 100

[[suite.run]]
optimizers = ["adam", "smmf"]
models = ["synthetic:tiny_lm"]
"#,
        "x",
    )
    .unwrap();
    cfg.out_dir = tmp.to_str().unwrap().to_string();
    let w = start_worker(1, 0);
    let opts = SuiteOptions {
        workers: Some(WorkerSpec { local: 1, remote: vec![w.addr.to_string()] }),
        lease_timeout_ms: 5_000,
        ..SuiteOptions::default()
    };
    let out = run_suite(&cfg, &opts).unwrap();
    assert_eq!(out.counts(), (4, 0, 0));
    // The remote worker got some of the queue; the local lane the rest.
    let done_remote = w.stats().done as usize;
    assert!(done_remote >= 1 && done_remote < 4, "split was {done_remote}/4 remote");
    CellClient::connect(&w.addr.to_string(), None).unwrap().shutdown().unwrap();
    w.wait();
    let _ = std::fs::remove_dir_all(tmp);
}
