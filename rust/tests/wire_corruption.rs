//! The cross-protocol corruption battery: one deterministic driver
//! ([`smmf_repro::util::fuzzwire`]) replayed against every
//! length-prefixed codec in the tree — `SMMFWIRE` v4 frames (including
//! the chunk-stream ops), `SMMFCELL` remote-suite frames, and
//! `SMMFCKPT` checkpoint images. The shared contract under test: a
//! damaged or hostile byte stream is rejected with an error — never a
//! panic, never an allocation sized by an unvalidated count. Truncation
//! at every strict prefix must be rejected; bit flips and fabricated
//! length/count fields must at worst decode to different-but-valid
//! data. A panicking decoder fails the test by construction (the
//! driver propagates it); an over-allocating one aborts the run, which
//! is just as loud.
//!
//! Failures reproduce bit-exactly: the PRNG families are seeded per
//! call and the truncation/inflation families are exhaustive.

use smmf_repro::coordinator::remote::protocol as cell;
use smmf_repro::optim::group::{self, GroupedConfig};
use smmf_repro::optim::schedule::LrSchedule;
use smmf_repro::optim::{self, GroupPolicy, OptKind, OptimConfig, ParamRole, ParamSpec, StatePolicy};
use smmf_repro::server::protocol::{self as wire, Contributor, EpochView, Frame, Msg, ServerStats};
use smmf_repro::tensor::Tensor;
use smmf_repro::train::checkpoint::{self, ConfigSection};
use smmf_repro::util::fuzzwire::fuzz_codec;

/// Every wire-encodable `SMMFWIRE` v4 op, small enough that the
/// exhaustive truncation family stays cheap.
fn wire_corpus() -> Vec<Vec<u8>> {
    let stats = ServerStats {
        step: 9,
        shards: 2,
        clients: 3,
        pushes: 27,
        busy: 1,
        snapshots: 1,
        epoch: 2,
        evictions: 0,
        respawns: 1,
        recovery_ms: 12,
        staleness: 2,
    };
    let view = EpochView { epoch: 3, next_step: 10, client: 1, members: vec![0, 1, 4] };
    let msgs = vec![
        Msg::PushBegin { client: 1, epoch: 2, step: 7, base_step: 6, n_tensors: 4 },
        Msg::PullParams { min_step: 0, mode: wire::PULL_DENSE },
        Msg::PullParams { min_step: 12, mode: wire::PULL_FACTORED },
        Msg::Snapshot { path: "/tmp/snap.bin".into() },
        Msg::Stats,
        Msg::Shutdown,
        Msg::Join,
        Msg::Leave { client: 2 },
        Msg::EpochInfo,
        Msg::Resend { tensor_idx: 3, seq: 9 },
        Msg::ChunkHeader { tensor_idx: 0, seq: 1, total: 3, start: 64, count: 64, tensor_len: 192 },
        Msg::ChunkData { tensor_idx: 0, seq: 1, bytes: (0..64u8).collect() },
        Msg::StreamEnd { step: 7, tensors: 4 },
        Msg::Ack { step: 7 },
        Msg::ParamsBegin { step: 7, mode: wire::PULL_FACTORED, n_tensors: 4 },
        Msg::SnapshotDone { bytes: 4096 },
        Msg::StatsReply(stats),
        Msg::Busy,
        Msg::Bye,
        Msg::Err { msg: "rejected for the test".into() },
        Msg::EpochReply(view),
        Msg::StaleEpoch { epoch: 3 },
        Msg::TooStale { applied: 5, required: 9 },
        Msg::LogHeader {
            model: "synthetic:tiny_lm".into(),
            optimizer: "smmf".into(),
            seed: 3,
            base_lr: 0.05,
            staleness: 2,
            first_step: 1,
        },
        Msg::LogCommit {
            step: 7,
            epoch: 2,
            contributors: vec![
                Contributor { client: 0, base_step: 6 },
                Contributor { client: 2, base_step: 5 },
            ],
            digest: 0xfeed_f00d,
            grads: vec![vec![0.5, -0.25, 2.0], vec![1.0]],
        },
    ];
    msgs.into_iter()
        .enumerate()
        .map(|(i, msg)| wire::encode(&Frame { request_id: 100 + i as u64, msg }))
        .collect()
}

/// Every `SMMFCELL` message, requests and replies.
fn cell_corpus() -> Vec<Vec<u8>> {
    let msgs = vec![
        cell::CellMsg::Submit {
            nonce: 0xabad_cafe,
            job: 3,
            run: "lr3e-4_smmf".into(),
            model: "synthetic:tiny_lm".into(),
            config: "[optim]\nlr = 3e-4\n".into(),
        },
        cell::CellMsg::Poll { nonce: 0xabad_cafe, job: 3 },
        cell::CellMsg::Ping,
        cell::CellMsg::Shutdown,
        cell::CellMsg::Accepted { job: 3 },
        cell::CellMsg::Running { job: 3 },
        cell::CellMsg::Done { job: 3 },
        cell::CellMsg::Failed { job: 3, note: "loss went non-finite".into() },
        cell::CellMsg::Busy,
        cell::CellMsg::Pong { running: 1, capacity: 4 },
        cell::CellMsg::Bye,
        cell::CellMsg::Err { msg: "unknown job".into() },
    ];
    msgs.into_iter()
        .enumerate()
        .map(|(i, msg)| cell::encode(&cell::CellFrame { request_id: 7 + i as u64, msg }))
        .collect()
}

/// One small-but-complete `SMMFCKPT` v2 image: PARAMS + TRAINER +
/// SCHEDULE + OPT (real SMMF factored blobs, sign plane included) +
/// CONFIG with a two-group table. Small on purpose — the truncation
/// family decodes every strict prefix.
fn ckpt_corpus() -> Vec<Vec<u8>> {
    let names = vec!["w1".to_string(), "b1".to_string()];
    let params = vec![
        Tensor::from_vec(&[2, 3], vec![1.0, -2.0, 3.0, 4.0, 5.5, -6.0]),
        Tensor::from_vec(&[3], vec![0.1, 0.2, 0.3]),
    ];
    let specs = vec![
        ParamSpec::new("w1", &[2, 3], ParamRole::Kernel),
        ParamSpec::new("b1", &[3], ParamRole::Bias),
    ];
    let mut gcfg =
        GroupedConfig::uniform(&OptimConfig { weight_decay: 0.01, ..OptimConfig::default() });
    gcfg.groups.push(GroupPolicy {
        name: "no_decay".into(),
        match_roles: vec![ParamRole::Bias],
        weight_decay: Some(0.0),
        state: StatePolicy::Dense,
        ..GroupPolicy::default()
    });
    let config = ConfigSection::from_config(&gcfg.base, &group::resolve(&specs, &gcfg));

    // Real optimizer state, so the OPT section carries representative
    // factored + sign-plane bytes rather than synthetic filler.
    let shapes = vec![vec![2usize, 3], vec![3]];
    let mut opt = optim::build(OptKind::Smmf, &shapes, &gcfg.base);
    let mut p = params.clone();
    let grads: Vec<Tensor> = shapes
        .iter()
        .map(|s| Tensor::from_vec(s, vec![0.1; s.iter().product()]))
        .collect();
    opt.step(&mut p, &grads);

    vec![checkpoint::snapshot_to_bytes(
        5,
        &names,
        &params,
        1e-2,
        &LrSchedule::Cosine { warmup: 2, total: 10, floor: 0.1 },
        OptKind::Smmf,
        5,
        opt.state_blobs(),
        &config,
    )]
}

#[test]
fn smmfwire_v4_rejects_corruption_without_panicking() {
    let rep = fuzz_codec("SMMFWIRE", &wire_corpus(), 0x51ff_0001, 64, 64, &mut |b| {
        wire::decode(b).map(|_| ()).map_err(|e| format!("{e:#}"))
    });
    // Every family ran and the strict-prefix family alone rejects a lot.
    assert!(rep.cases > 2_000, "{rep:?}");
    assert!(rep.rejected > rep.accepted, "{rep:?}");
}

#[test]
fn smmfcell_rejects_corruption_without_panicking() {
    let rep = fuzz_codec("SMMFCELL", &cell_corpus(), 0x51ff_0002, 64, 64, &mut |b| {
        cell::decode(b).map(|_| ()).map_err(|e| format!("{e:#}"))
    });
    assert!(rep.cases > 1_000, "{rep:?}");
    assert!(rep.rejected > rep.accepted, "{rep:?}");
}

#[test]
fn smmfckpt_rejects_corruption_without_panicking() {
    let rep = fuzz_codec("SMMFCKPT", &ckpt_corpus(), 0x51ff_0003, 256, 256, &mut |b| {
        checkpoint::load_bytes(b).map(|_| ()).map_err(|e| format!("{e:#}"))
    });
    assert!(rep.cases > 500, "{rep:?}");
    assert!(rep.rejected > 0, "{rep:?}");
}
