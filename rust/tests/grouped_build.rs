//! Grouped optimizer API correctness (ISSUE 3 acceptance).
//!
//! * `build_grouped` with a single all-default group must be
//!   bit-identical to the legacy `build` path, for all seven `OptKind`s,
//!   at `threads ∈ {1, 4}` (property test over random inventories).
//! * Weight-decay exemption: bias/norm tensors in a `wd = 0` group must
//!   follow exactly the trajectory of a globally-undecayed run, while
//!   kernel tensors keep the decayed trajectory (per-tensor updates are
//!   independent given a fixed gradient stream).
//! * A grouped run (bias/norm exemption + `StatePolicy::Dense` for
//!   rank-1 tensors under SMMF) trains, checkpoints through a real v2
//!   file with a CONFIG section, and resumes bit-identically.

use std::path::PathBuf;

use smmf_repro::optim::group::{self, GroupedConfig, ParamRole, ParamSpec, StatePolicy};
use smmf_repro::optim::schedule::LrSchedule;
use smmf_repro::optim::{
    build, build_grouped, GroupPolicy, OptKind, OptimConfig, Optimizer, StateSerde,
};
use smmf_repro::tensor::Tensor;
use smmf_repro::train::checkpoint::{self, ConfigSection, OptSection, ScheduleSection};
use smmf_repro::util::prop;
use smmf_repro::util::rng::Pcg32;

fn rand_tensors(rng: &mut Pcg32, shapes: &[Vec<usize>], scale: f32) -> Vec<Tensor> {
    shapes
        .iter()
        .map(|s| {
            let mut t = Tensor::zeros(s);
            rng.fill_normal(t.data_mut(), scale);
            t
        })
        .collect()
}

fn tmp(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("smmf_grouped_{tag}_{}.bin", std::process::id()))
}

/// A small transformer-flavored inventory exercising every role.
fn role_specs() -> Vec<ParamSpec> {
    vec![
        ParamSpec::new("encoder.0.attn.q.weight", &[24, 24], ParamRole::Kernel),
        ParamSpec::new("encoder.0.attn.q.bias", &[24], ParamRole::Bias),
        ParamSpec::new("encoder.0.ln1.weight", &[24], ParamRole::Norm),
        ParamSpec::new("encoder.0.ln1.bias", &[24], ParamRole::Norm),
        ParamSpec::new("tok_emb.weight", &[50, 16], ParamRole::Embedding),
        ParamSpec::new("head.weight", &[10, 16], ParamRole::Kernel),
    ]
}

#[test]
fn prop_single_default_group_is_bit_identical_to_legacy_build() {
    prop::cases(12, |rng| {
        let n_tensors = 1 + rng.below(4);
        let shapes: Vec<Vec<usize>> =
            (0..n_tensors).map(|_| prop::gen_shape(rng, 4, 2048)).collect();
        let specs: Vec<ParamSpec> = shapes
            .iter()
            .enumerate()
            .map(|(i, s)| ParamSpec::inferred(format!("p{i}.weight"), s))
            .collect();
        let p0 = rand_tensors(rng, &shapes, 0.5);
        let grads: Vec<Vec<Tensor>> =
            (0..3).map(|_| rand_tensors(rng, &shapes, 0.1)).collect();
        for kind in OptKind::every() {
            for threads in [1usize, 4] {
                let cfg = OptimConfig {
                    lr: 0.01,
                    weight_decay: 0.01,
                    threads,
                    ..OptimConfig::paper_defaults(kind)
                };
                let mut legacy = build(kind, &shapes, &cfg);
                let mut grouped = build_grouped(kind, &specs, &GroupedConfig::uniform(&cfg));
                let mut p1 = p0.clone();
                let mut p2 = p0.clone();
                for g in &grads {
                    legacy.step(&mut p1, g);
                    grouped.step(&mut p2, g);
                }
                assert_eq!(
                    p1,
                    p2,
                    "{} at threads={threads}: grouped default diverged from legacy",
                    kind.name()
                );
                assert_eq!(legacy.state_bytes(), grouped.state_bytes(), "{}", kind.name());
                assert_eq!(legacy.state_blobs(), grouped.state_blobs(), "{}", kind.name());
            }
        }
    });
}

#[test]
fn weight_decay_exemption_tracks_undecayed_trajectory() {
    let specs = role_specs();
    let shapes: Vec<Vec<usize>> = specs.iter().map(|s| s.shape.clone()).collect();
    let mut rng = Pcg32::new(77);
    let p0 = rand_tensors(&mut rng, &shapes, 0.5);
    let grads: Vec<Vec<Tensor>> = (0..4).map(|_| rand_tensors(&mut rng, &shapes, 0.1)).collect();
    for kind in OptKind::every() {
        let decayed = OptimConfig {
            lr: 0.01,
            weight_decay: 0.05,
            ..OptimConfig::paper_defaults(kind)
        };
        let undecayed = OptimConfig { weight_decay: 0.0, ..decayed.clone() };
        let mut gcfg = GroupedConfig::uniform(&decayed);
        gcfg.groups.push(GroupPolicy {
            name: "no_decay".into(),
            match_roles: vec![ParamRole::Bias, ParamRole::Norm],
            weight_decay: Some(0.0),
            ..GroupPolicy::default()
        });

        let run = |opt: &mut Box<dyn Optimizer>| -> Vec<Tensor> {
            let mut p = p0.clone();
            for g in &grads {
                opt.step(&mut p, g);
            }
            p
        };
        let grouped = run(&mut build_grouped(kind, &specs, &gcfg));
        let all_decayed = run(&mut build(kind, &shapes, &decayed));
        let none_decayed = run(&mut build(kind, &shapes, &undecayed));
        for (i, spec) in specs.iter().enumerate() {
            let exempt = matches!(spec.role, ParamRole::Bias | ParamRole::Norm);
            let expect = if exempt { &none_decayed[i] } else { &all_decayed[i] };
            assert_eq!(
                &grouped[i],
                expect,
                "{}: tensor {} ({}) {} trajectory",
                kind.name(),
                spec.name,
                spec.role.name(),
                if exempt { "exempt" } else { "decayed" },
            );
        }
    }
}

#[test]
fn lr_scale_matches_rescaled_base_lr() {
    // An embedding group at lr_scale 0.5 must follow exactly the
    // trajectory of a run whose base lr is halved (per-tensor updates
    // are independent under a fixed gradient stream).
    let specs = role_specs();
    let shapes: Vec<Vec<usize>> = specs.iter().map(|s| s.shape.clone()).collect();
    let mut rng = Pcg32::new(13);
    let p0 = rand_tensors(&mut rng, &shapes, 0.5);
    let grads: Vec<Vec<Tensor>> = (0..3).map(|_| rand_tensors(&mut rng, &shapes, 0.1)).collect();
    for kind in [OptKind::Adam, OptKind::Smmf, OptKind::Sgd] {
        let base = OptimConfig { lr: 0.02, ..OptimConfig::paper_defaults(kind) };
        let halved = OptimConfig { lr: 0.02 * 0.5, ..base.clone() };
        let mut gcfg = GroupedConfig::uniform(&base);
        gcfg.groups.push(GroupPolicy {
            name: "emb".into(),
            match_roles: vec![ParamRole::Embedding],
            lr_scale: 0.5,
            ..GroupPolicy::default()
        });
        let run = |opt: &mut Box<dyn Optimizer>| -> Vec<Tensor> {
            let mut p = p0.clone();
            for g in &grads {
                opt.step(&mut p, g);
            }
            p
        };
        let grouped = run(&mut build_grouped(kind, &specs, &gcfg));
        let full = run(&mut build(kind, &shapes, &base));
        let half = run(&mut build(kind, &shapes, &halved));
        for (i, spec) in specs.iter().enumerate() {
            let expect =
                if spec.role == ParamRole::Embedding { &half[i] } else { &full[i] };
            assert_eq!(&grouped[i], expect, "{}: {}", kind.name(), spec.name);
        }
    }
}

/// The issue's acceptance scenario: bias/norm weight-decay exemption plus
/// `StatePolicy::Dense` for rank-1 tensors under SMMF — train, save
/// through a real v2 file (with CONFIG), rebuild from the file alone,
/// train on: bit-identical to the uninterrupted run.
#[test]
fn grouped_run_checkpoints_and_resumes_bit_identically() {
    let specs = role_specs();
    let shapes: Vec<Vec<usize>> = specs.iter().map(|s| s.shape.clone()).collect();
    let names: Vec<String> = specs.iter().map(|s| s.name.clone()).collect();
    let (half, total) = (3usize, 6usize);
    for kind in [OptKind::Smmf, OptKind::Adam, OptKind::Adafactor] {
        for threads in [1usize, 4] {
            let base = OptimConfig {
                lr: 0.01,
                weight_decay: 0.05,
                threads,
                ..OptimConfig::paper_defaults(kind)
            };
            let mut gcfg = GroupedConfig::uniform(&base);
            gcfg.groups.push(GroupPolicy {
                name: "no_decay_dense".into(),
                match_roles: vec![ParamRole::Bias, ParamRole::Norm],
                weight_decay: Some(0.0),
                state: StatePolicy::Dense,
                ..GroupPolicy::default()
            });
            let res = group::resolve(&specs, &gcfg);
            let config = ConfigSection::from_config(&base, &res);
            let path = tmp(&format!("{}_t{threads}", kind.name()));

            // Straight run.
            let straight = {
                let mut opt = build_grouped(kind, &specs, &gcfg);
                let mut init_rng = Pcg32::new(7);
                let mut p = rand_tensors(&mut init_rng, &shapes, 0.5);
                let mut data_rng = Pcg32::new(123);
                for _ in 0..total {
                    let g = rand_tensors(&mut data_rng, &shapes, 0.1);
                    opt.step(&mut p, &g);
                }
                p
            };

            // Half, save, drop everything, reload, finish.
            {
                let mut opt = build_grouped(kind, &specs, &gcfg);
                let mut init_rng = Pcg32::new(7);
                let mut p = rand_tensors(&mut init_rng, &shapes, 0.5);
                let mut data_rng = Pcg32::new(123);
                for _ in 0..half {
                    let g = rand_tensors(&mut data_rng, &shapes, 0.1);
                    opt.step(&mut p, &g);
                }
                let sched = ScheduleSection { base_lr: base.lr, schedule: LrSchedule::Constant };
                let opt_sec =
                    OptSection { kind, opt_step: opt.opt_step(), blobs: opt.state_blobs() };
                checkpoint::save_v2(
                    &path,
                    half as u64,
                    &names,
                    &p,
                    Some(data_rng.state()),
                    Some(&sched),
                    Some(&opt_sec),
                    Some(&config),
                )
                .unwrap();
            }
            let ck = checkpoint::load_any(&path).unwrap();
            std::fs::remove_file(&path).unwrap();
            let loaded_cfg = ck.config.expect("grouped checkpoint carries CONFIG");
            assert!(loaded_cfg.mismatches(&config).is_empty());
            // ...and a drifted recipe is detectable before any state load
            let mut drifted = config.clone();
            drifted.groups[1].weight_decay = 0.05;
            assert!(!loaded_cfg.mismatches(&drifted).is_empty());

            let o = ck.opt.expect("optimizer state present");
            let mut opt = build_grouped(kind, &specs, &gcfg);
            opt.load_state_blobs(&o.blobs).unwrap();
            opt.set_opt_step(o.opt_step);
            let mut p = ck.params;
            let (state, inc) = ck.rng.unwrap();
            let mut data_rng = Pcg32::from_state(state, inc);
            for _ in half..total {
                let g = rand_tensors(&mut data_rng, &shapes, 0.1);
                opt.step(&mut p, &g);
            }
            assert_eq!(straight, p, "{} threads={threads}: grouped resume diverged", kind.name());
        }
    }
}

#[test]
fn frozen_and_stateless_groups_behave() {
    let specs = role_specs();
    let shapes: Vec<Vec<usize>> = specs.iter().map(|s| s.shape.clone()).collect();
    let mut rng = Pcg32::new(5);
    let p0 = rand_tensors(&mut rng, &shapes, 0.5);
    let grads: Vec<Vec<Tensor>> = (0..2).map(|_| rand_tensors(&mut rng, &shapes, 0.1)).collect();
    for kind in OptKind::every() {
        // relative_step off so every optimizer's stateless step is
        // exactly `lr * g` (Adafactor would otherwise scale by RMS(p)).
        let base = OptimConfig {
            lr: 0.01,
            relative_step: false,
            ..OptimConfig::paper_defaults(kind)
        };
        let mut gcfg = GroupedConfig::uniform(&base);
        gcfg.groups.push(GroupPolicy {
            name: "frozen_emb".into(),
            match_roles: vec![ParamRole::Embedding],
            frozen: true,
            ..GroupPolicy::default()
        });
        gcfg.groups.push(GroupPolicy {
            name: "stateless_head".into(),
            match_names: vec!["head.*".into()],
            state: StatePolicy::None,
            ..GroupPolicy::default()
        });
        let mut opt = build_grouped(kind, &specs, &gcfg);
        let mut p = p0.clone();
        for g in &grads {
            opt.step(&mut p, g);
        }
        // frozen embedding untouched
        assert_eq!(p[4], p0[4], "{}: frozen tensor moved", kind.name());
        // stateless head: plain w -= lr * g trajectory
        let mut expect = p0[5].clone();
        for g in &grads {
            for (w, &gij) in expect.data_mut().iter_mut().zip(g[5].data()) {
                *w -= 0.01 * gij;
            }
        }
        assert_eq!(p[5], expect, "{}: stateless update is not plain SGD", kind.name());
        // blobs roundtrip with the reduced layouts
        let blobs = opt.state_blobs();
        let mut fresh = build_grouped(kind, &specs, &gcfg);
        fresh.load_state_blobs(&blobs).unwrap();
        fresh.set_opt_step(opt.opt_step());
        assert_eq!(fresh.state_blobs(), blobs, "{}", kind.name());
        // ...and a legacy (ungrouped) optimizer refuses these blobs
        // (layout mismatch), except SGD-without-momentum whose stateless
        // blob is the native momentum-free encoding either way.
        if kind != OptKind::Sgd || base.momentum != 0.0 {
            let mut legacy = build(kind, &shapes, &base);
            assert!(
                legacy.load_state_blobs(&blobs).is_err(),
                "{}: legacy build accepted grouped blobs",
                kind.name()
            );
        }
    }
}
