//! Wire-codec property and corruption tests for the `SMMFWIRE`
//! protocol (`server::protocol`), in the same strict-decode style as the
//! `optim/blob.rs` and checkpoint-container tests: every op roundtrips,
//! every strict prefix of a valid frame errors cleanly, hostile length
//! fields are rejected *before* any allocation, and bad magic/version/op
//! bytes produce context-rich errors — never a panic or an OOM.

use smmf_repro::server::protocol::{
    self, decode, encode, read_frame, write_frame, Contributor, EpochView, Frame, Msg,
    ServerStats, HEADER_LEN, MAX_PAYLOAD, OP_PUSH_GRAD,
};
use smmf_repro::util::prop;

fn all_ops() -> Vec<Msg> {
    vec![
        Msg::PushGrad {
            client: 3,
            epoch: 2,
            step: 41,
            base_step: 38,
            grads: vec![vec![1.0, -2.5, 0.0], vec![], vec![f32::MIN, f32::MAX]],
        },
        Msg::PullParams { min_step: 0 },
        Msg::PullParams { min_step: 37 },
        Msg::Snapshot { path: "runs/server/snapshot.bin".into() },
        Msg::Stats,
        Msg::Shutdown,
        Msg::Join,
        Msg::Leave { client: 5 },
        Msg::EpochInfo,
        Msg::Ack { step: 7 },
        Msg::Params { step: 6, tensors: vec![vec![0.25; 17], vec![-1.0]] },
        Msg::SnapshotDone { bytes: 123_456_789 },
        Msg::StatsReply(ServerStats {
            step: 9,
            shards: 2,
            clients: 4,
            pushes: 36,
            busy: 1,
            snapshots: 2,
            epoch: 3,
            evictions: 1,
            respawns: 2,
            recovery_ms: 48,
            staleness: 4,
        }),
        Msg::EpochReply(EpochView {
            epoch: 4,
            next_step: 10,
            client: protocol::NO_CLIENT,
            members: vec![0, 2, 3, 7],
        }),
        Msg::EpochReply(EpochView { epoch: 1, next_step: 1, client: 0, members: vec![0] }),
        Msg::StaleEpoch { epoch: 6 },
        Msg::TooStale { applied: 12, required: 9 },
        Msg::Busy,
        Msg::Bye,
        Msg::Err { msg: "client 9 already pushed for step 3".into() },
        Msg::LogHeader {
            model: "synthetic:tiny_lm".into(),
            optimizer: "smmf".into(),
            seed: 42,
            base_lr: 1e-3,
            staleness: 3,
            first_step: 1,
        },
        Msg::LogCommit {
            step: 5,
            epoch: 2,
            contributors: vec![
                Contributor { client: 0, base_step: 4 },
                Contributor { client: 2, base_step: 2 },
            ],
            digest: 0xdead_beef_cafe_f00d,
            grads: vec![vec![0.5, -0.25], vec![]],
        },
    ]
}

#[test]
fn every_op_roundtrips_through_slice_and_stream() {
    for (i, msg) in all_ops().into_iter().enumerate() {
        let frame = Frame { request_id: 1000 + i as u64, msg };
        // slice path
        let bytes = encode(&frame);
        assert_eq!(decode(&bytes).unwrap(), frame, "op {}", frame.msg.name());
        // stream path
        let mut cur = std::io::Cursor::new(bytes);
        assert_eq!(read_frame(&mut cur).unwrap(), frame, "op {}", frame.msg.name());
    }
}

#[test]
fn back_to_back_frames_stream_cleanly() {
    let frames: Vec<Frame> = all_ops()
        .into_iter()
        .enumerate()
        .map(|(i, msg)| Frame { request_id: i as u64, msg })
        .collect();
    let mut buf = Vec::new();
    for f in &frames {
        write_frame(&mut buf, f).unwrap();
    }
    let mut cur = std::io::Cursor::new(buf);
    for f in &frames {
        assert_eq!(&read_frame(&mut cur).unwrap(), f);
    }
    // stream exhausted: the next read errors instead of hanging
    assert!(read_frame(&mut cur).is_err());
}

#[test]
fn every_strict_prefix_of_every_op_errors() {
    for msg in all_ops() {
        let name = msg.name();
        let full = encode(&Frame { request_id: 5, msg });
        for cut in 0..full.len() {
            assert!(decode(&full[..cut]).is_err(), "{name}: prefix of {cut} bytes parsed");
            let mut cur = std::io::Cursor::new(&full[..cut]);
            assert!(read_frame(&mut cur).is_err(), "{name}: stream prefix of {cut} bytes parsed");
        }
        assert!(decode(&full).is_ok(), "{name}");
    }
}

#[test]
fn bad_magic_version_and_op_are_rejected() {
    let good = encode(&Frame { request_id: 1, msg: Msg::PullParams { min_step: 0 } });

    // flip each magic byte
    for i in 0..8 {
        let mut bad = good.clone();
        bad[i] ^= 0xff;
        let e = decode(&bad).unwrap_err();
        assert!(format!("{e:#}").contains("magic"), "byte {i}: {e:#}");
    }
    // wrong version
    let mut bad = good.clone();
    bad[8..12].copy_from_slice(&99u32.to_le_bytes());
    let e = decode(&bad).unwrap_err();
    assert!(format!("{e:#}").contains("version"), "{e:#}");
    // unknown op byte (offset 20)
    let mut bad = good.clone();
    bad[20] = 0xee;
    let e = decode(&bad).unwrap_err();
    assert!(format!("{e:#}").contains("unknown"), "{e:#}");
}

#[test]
fn oversized_length_prefix_is_rejected_before_allocation() {
    // Header claims a payload beyond MAX_PAYLOAD: both decode paths must
    // refuse from the header alone. A reader that trusted this length
    // would try to allocate 2^60 bytes — the test passing at all is the
    // proof it never gets there.
    let good = encode(&Frame { request_id: 1, msg: Msg::Stats });
    let mut bad = good.clone();
    bad[21..29].copy_from_slice(&(1u64 << 60).to_le_bytes());
    let e = decode(&bad).unwrap_err();
    assert!(format!("{e:#}").contains("cap"), "{e:#}");
    let mut cur = std::io::Cursor::new(&bad);
    assert!(read_frame(&mut cur).is_err());
    // just over the cap is also refused
    let mut bad = good.clone();
    bad[21..29].copy_from_slice(&(MAX_PAYLOAD + 1).to_le_bytes());
    assert!(decode(&bad).is_err());
}

/// Hand-build a PushGrad frame whose tensor claims more f32 elements
/// than the payload holds: the remaining-bytes check must fire before
/// the element buffer is allocated.
#[test]
fn fabricated_tensor_count_is_caught_by_the_remaining_bytes_check() {
    use smmf_repro::optim::blob::BlobWriter;
    let mut p = BlobWriter::new();
    p.u32(0); // client
    p.u64(1); // epoch
    p.u64(1); // step
    p.u64(0); // base_step
    p.u32(1); // one tensor…
    p.u64(1 << 40); // …claiming 2^40 elements
    let payload = p.finish();
    let mut w = BlobWriter::new();
    w.bytes(protocol::MAGIC);
    w.u32(protocol::VERSION);
    w.u64(9);
    w.u8(OP_PUSH_GRAD);
    w.u64(payload.len() as u64);
    w.bytes(&payload);
    let e = decode(&w.finish()).unwrap_err();
    let msg = format!("{e:#}");
    assert!(msg.contains("remain"), "{msg}");

    // absurd tensor *count* is capped too
    let mut p = BlobWriter::new();
    p.u32(0);
    p.u64(1);
    p.u64(1);
    p.u64(0);
    p.u32(u32::MAX);
    let payload = p.finish();
    let mut w = BlobWriter::new();
    w.bytes(protocol::MAGIC);
    w.u32(protocol::VERSION);
    w.u64(9);
    w.u8(OP_PUSH_GRAD);
    w.u64(payload.len() as u64);
    w.bytes(&payload);
    let e = decode(&w.finish()).unwrap_err();
    assert!(format!("{e:#}").contains("cap"), "{e:#}");
}

#[test]
fn trailing_payload_bytes_are_rejected() {
    // An Ack payload with one extra byte: decode_payload's finish()
    // must flag it (a desynced stream must not be silently accepted).
    let good = encode(&Frame { request_id: 2, msg: Msg::Ack { step: 3 } });
    let mut bad = good.clone();
    bad.push(0);
    // fix up the declared length to cover the extra byte
    let len = (bad.len() - HEADER_LEN) as u64;
    bad[21..29].copy_from_slice(&len.to_le_bytes());
    let e = decode(&bad).unwrap_err();
    assert!(format!("{e:#}").contains("trailing"), "{e:#}");

    // extra bytes *after* the declared payload are flagged by decode too
    let mut bad = good;
    bad.push(0);
    assert!(decode(&bad).is_err());
}

#[test]
fn string_caps_apply_to_snapshot_and_err() {
    let long = "x".repeat(protocol::MAX_STR_LEN + 1);
    // An over-long snapshot path is NOT clipped on encode (a silently
    // truncated path would be worse) — the decoder rejects the frame.
    let bytes = encode(&Frame { request_id: 1, msg: Msg::Snapshot { path: long.clone() } });
    let e = decode(&bytes).unwrap_err();
    assert!(format!("{e:#}").contains("cap"), "{e:#}");
    // An over-long Err message IS clipped on encode (char-boundary
    // safe), so an anyhow chain longer than the cap still reaches the
    // peer instead of killing the connection.
    let bytes = encode(&Frame { request_id: 1, msg: Msg::Err { msg: format!("{long}é") } });
    match decode(&bytes).unwrap().msg {
        Msg::Err { msg } => {
            assert_eq!(msg.len(), protocol::MAX_STR_LEN);
            assert!(msg.chars().all(|c| c == 'x'));
        }
        other => panic!("expected Err, got {}", other.name()),
    }
    // clipping lands on a char boundary even when a multibyte char
    // straddles the cap
    let straddle = format!("{}é tail", "x".repeat(protocol::MAX_STR_LEN - 1));
    let bytes = encode(&Frame { request_id: 1, msg: Msg::Err { msg: straddle } });
    match decode(&bytes).unwrap().msg {
        Msg::Err { msg } => assert_eq!(msg.len(), protocol::MAX_STR_LEN - 1),
        other => panic!("expected Err, got {}", other.name()),
    }
    // at the cap is fine, untouched
    let ok = "y".repeat(protocol::MAX_STR_LEN);
    let f = Frame { request_id: 1, msg: Msg::Snapshot { path: ok } };
    assert_eq!(decode(&encode(&f)).unwrap(), f);
}

#[test]
fn grads_payload_bytes_matches_the_encoder() {
    let shapes = vec![vec![3, 2], vec![7], vec![1]];
    let grads: Vec<Vec<f32>> =
        shapes.iter().map(|s| vec![0.5; s.iter().product()]).collect();
    let frame = Frame {
        request_id: 1,
        msg: Msg::PushGrad { client: 0, epoch: 1, step: 1, base_step: 0, grads },
    };
    let expect = protocol::grads_payload_bytes(&shapes);
    assert_eq!(encode(&frame).len() as u64, HEADER_LEN as u64 + expect);
}

/// Hand-build an EpochReply whose member list claims more entries than
/// [`protocol::MAX_MEMBERS`] (cap check) or than the payload holds
/// (remaining-bytes check): both must fire before the member buffer is
/// allocated.
#[test]
fn fabricated_member_count_is_caught_before_allocation() {
    use smmf_repro::optim::blob::BlobWriter;
    let build = |n_members: u32| {
        let mut p = BlobWriter::new();
        p.u64(2); // epoch
        p.u64(5); // next_step
        p.u32(protocol::NO_CLIENT);
        p.u32(n_members); // …but no member bytes follow
        let payload = p.finish();
        let mut w = BlobWriter::new();
        w.bytes(protocol::MAGIC);
        w.u32(protocol::VERSION);
        w.u64(9);
        w.u8(protocol::OP_EPOCH_REPLY);
        w.u64(payload.len() as u64);
        w.bytes(&payload);
        w.finish()
    };
    let e = decode(&build(protocol::MAX_MEMBERS as u32 + 1)).unwrap_err();
    assert!(format!("{e:#}").contains("cap"), "{e:#}");
    let e = decode(&build(16)).unwrap_err();
    assert!(format!("{e:#}").contains("remain"), "{e:#}");
}

/// Hand-build a LogCommit frame whose contributor list claims more
/// entries than [`protocol::MAX_MEMBERS`] (cap check) or than the
/// payload holds (remaining-bytes check): both must fire before the
/// contributor buffer is allocated — the commit-log loader feeds
/// attacker-controlled files through this exact decoder.
#[test]
fn fabricated_commit_contributor_count_is_caught_before_allocation() {
    use smmf_repro::optim::blob::BlobWriter;
    let build = |n: u32| {
        let mut p = BlobWriter::new();
        p.u64(5); // step
        p.u64(2); // epoch
        p.u32(n); // contributor count… but no contributor bytes follow
        let payload = p.finish();
        let mut w = BlobWriter::new();
        w.bytes(protocol::MAGIC);
        w.u32(protocol::VERSION);
        w.u64(9);
        w.u8(protocol::OP_LOG_COMMIT);
        w.u64(payload.len() as u64);
        w.bytes(&payload);
        w.finish()
    };
    let e = decode(&build(protocol::MAX_MEMBERS as u32 + 1)).unwrap_err();
    assert!(format!("{e:#}").contains("cap"), "{e:#}");
    let e = decode(&build(16)).unwrap_err();
    assert!(format!("{e:#}").contains("remain"), "{e:#}");
}

#[test]
fn prop_random_corruption_never_panics() {
    // Flip random bytes of random valid frames: decoding must always
    // return (Ok for the rare no-op flip of f32 payload bytes, Err
    // otherwise) — never panic, never hang, never over-allocate.
    let ops = all_ops();
    prop::cases(200, |rng| {
        let frame = Frame {
            request_id: rng.next_u64(),
            msg: ops[rng.below(ops.len())].clone(),
        };
        let mut bytes = encode(&frame);
        let flips = 1 + rng.below(4);
        for _ in 0..flips {
            let i = rng.below(bytes.len());
            bytes[i] ^= 1u8 << rng.below(8);
        }
        let _ = decode(&bytes);
        // truncate at a random point too
        let cut = rng.below(bytes.len() + 1);
        let _ = decode(&bytes[..cut]);
    });
}
