//! Wire-codec property and corruption tests for the `SMMFWIRE` v4
//! protocol (`server::protocol`), in the same strict-decode style as the
//! `optim/blob.rs` and checkpoint-container tests: every op roundtrips,
//! every strict prefix of a valid frame errors cleanly, hostile length
//! and count fields are rejected *before* any allocation, and bad
//! magic/version/op bytes produce context-rich errors — never a panic
//! or an OOM. The cross-codec corruption battery lives in
//! `tests/wire_corruption.rs`; this file pins the v4-specific shapes
//! (chunk ops, split payload caps, internal-variant panics).

use smmf_repro::server::protocol::{
    self, chunk_plan, decode, decode_header, encode, read_frame, write_frame, ChunkAssembler,
    ChunkError, Contributor, EpochView, Frame, Msg, ServerStats, CHUNK_MAX_BYTES, HEADER_LEN,
    MAX_CHUNKS_PER_TENSOR, MAX_FILE_PAYLOAD, MAX_PAYLOAD, OP_CHUNK_HEADER, OP_LOG_COMMIT,
    OP_PARAMS_BEGIN, OP_PUSH_BEGIN, OP_STATS, PULL_DENSE, PULL_FACTORED,
};
use smmf_repro::util::prop;

fn all_ops() -> Vec<Msg> {
    vec![
        Msg::PushBegin { client: 3, epoch: 2, step: 41, base_step: 38, n_tensors: 9 },
        Msg::PullParams { min_step: 0, mode: PULL_DENSE },
        Msg::PullParams { min_step: 37, mode: PULL_FACTORED },
        Msg::Snapshot { path: "runs/server/snapshot.bin".into() },
        Msg::Stats,
        Msg::MetricsDump,
        Msg::MetricsText { text: "# TYPE smmf_server_pushes_total counter\nsmmf_server_pushes_total 200\n".into() },
        Msg::MetricsText { text: String::new() },
        Msg::Shutdown,
        Msg::Join,
        Msg::Leave { client: 5 },
        Msg::EpochInfo,
        Msg::Resend { tensor_idx: 4, seq: 17 },
        Msg::ChunkHeader {
            tensor_idx: 2,
            seq: 1,
            total: 3,
            start: 262_144,
            count: 262_144,
            tensor_len: 590_000,
        },
        Msg::ChunkData { tensor_idx: 2, seq: 1, bytes: vec![0xAB; 1024] },
        Msg::ChunkData { tensor_idx: 0, seq: 0, bytes: Vec::new() },
        Msg::StreamEnd { step: 41, tensors: 9 },
        Msg::Ack { step: 7 },
        Msg::ParamsBegin { step: 6, mode: PULL_FACTORED, n_tensors: 9 },
        Msg::SnapshotDone { bytes: 123_456_789 },
        Msg::StatsReply(ServerStats {
            step: 9,
            shards: 2,
            clients: 4,
            pushes: 36,
            busy: 1,
            snapshots: 2,
            epoch: 3,
            evictions: 1,
            respawns: 2,
            recovery_ms: 48,
            staleness: 4,
        }),
        Msg::EpochReply(EpochView {
            epoch: 4,
            next_step: 10,
            client: protocol::NO_CLIENT,
            members: vec![0, 2, 3, 7],
        }),
        Msg::EpochReply(EpochView { epoch: 1, next_step: 1, client: 0, members: vec![0] }),
        Msg::StaleEpoch { epoch: 6 },
        Msg::TooStale { applied: 12, required: 9 },
        Msg::Busy,
        Msg::Bye,
        Msg::Err { msg: "client 9 already pushed for step 3".into() },
        Msg::LogHeader {
            model: "synthetic:tiny_lm".into(),
            optimizer: "smmf".into(),
            seed: 42,
            base_lr: 1e-3,
            staleness: 3,
            first_step: 1,
        },
        Msg::LogCommit {
            step: 5,
            epoch: 2,
            contributors: vec![
                Contributor { client: 0, base_step: 4 },
                Contributor { client: 2, base_step: 2 },
            ],
            digest: 0xdead_beef_cafe_f00d,
            grads: vec![vec![0.5, -0.25], vec![]],
        },
    ]
}

#[test]
fn every_op_roundtrips_through_slice_and_stream() {
    for (i, msg) in all_ops().into_iter().enumerate() {
        let frame = Frame { request_id: 1000 + i as u64, msg };
        // slice path
        let bytes = encode(&frame);
        assert_eq!(decode(&bytes).unwrap(), frame, "op {}", frame.msg.name());
        // stream path
        let mut cur = std::io::Cursor::new(bytes);
        assert_eq!(read_frame(&mut cur).unwrap(), frame, "op {}", frame.msg.name());
    }
}

#[test]
fn back_to_back_frames_stream_cleanly() {
    let frames: Vec<Frame> = all_ops()
        .into_iter()
        .enumerate()
        .map(|(i, msg)| Frame { request_id: i as u64, msg })
        .collect();
    let mut buf = Vec::new();
    for f in &frames {
        write_frame(&mut buf, f).unwrap();
    }
    let mut cur = std::io::Cursor::new(buf);
    for f in &frames {
        assert_eq!(&read_frame(&mut cur).unwrap(), f);
    }
    // stream exhausted: the next read errors instead of hanging
    assert!(read_frame(&mut cur).is_err());
}

#[test]
fn every_strict_prefix_of_every_op_errors() {
    for msg in all_ops() {
        let name = msg.name();
        let full = encode(&Frame { request_id: 5, msg });
        for cut in 0..full.len() {
            assert!(decode(&full[..cut]).is_err(), "{name}: prefix of {cut} bytes parsed");
            let mut cur = std::io::Cursor::new(&full[..cut]);
            assert!(read_frame(&mut cur).is_err(), "{name}: stream prefix of {cut} bytes parsed");
        }
        assert!(decode(&full).is_ok(), "{name}");
    }
}

/// The internal coordinator-channel variants have no v4 wire encoding —
/// framing one is a programming error that must fail loudly, not ship a
/// silently wrong frame.
#[test]
#[should_panic(expected = "coordinator-internal")]
fn internal_push_grad_has_no_wire_encoding() {
    encode(&Frame {
        request_id: 1,
        msg: Msg::PushGrad { client: 0, epoch: 1, step: 1, base_step: 0, grads: vec![] },
    });
}

#[test]
#[should_panic(expected = "coordinator-internal")]
fn internal_params_has_no_wire_encoding() {
    encode(&Frame { request_id: 1, msg: Msg::Params { step: 1, tensors: vec![] } });
}

#[test]
fn bad_magic_version_and_op_are_rejected() {
    let good = encode(&Frame { request_id: 1, msg: Msg::Stats });

    // flip each magic byte
    for i in 0..8 {
        let mut bad = good.clone();
        bad[i] ^= 0xff;
        let e = decode(&bad).unwrap_err();
        assert!(format!("{e:#}").contains("magic"), "byte {i}: {e:#}");
    }
    // wrong version — v3 peers (and v3 commit logs) are refused outright
    for v in [1u32, 2, 3, 99] {
        let mut bad = good.clone();
        bad[8..12].copy_from_slice(&v.to_le_bytes());
        let e = decode(&bad).unwrap_err();
        assert!(format!("{e:#}").contains("version"), "v{v}: {e:#}");
    }
    // unknown op byte (offset 20)
    let mut bad = good.clone();
    bad[20] = 0xee;
    let e = decode(&bad).unwrap_err();
    assert!(format!("{e:#}").contains("unknown"), "{e:#}");
}

#[test]
fn split_payload_caps_apply_per_op_range() {
    // Header claims a payload beyond MAX_PAYLOAD: a connection op must
    // refuse from the header alone — a reader that trusted this length
    // would try to allocate 2^60 bytes.
    let good = encode(&Frame { request_id: 1, msg: Msg::Stats });
    let mut bad = good.clone();
    bad[21..29].copy_from_slice(&(1u64 << 60).to_le_bytes());
    let e = decode(&bad).unwrap_err();
    assert!(format!("{e:#}").contains("cap"), "{e:#}");
    let mut cur = std::io::Cursor::new(&bad);
    assert!(read_frame(&mut cur).is_err());

    // Just over the connection cap is refused for connection ops…
    let hdr_with = |op: u8, len: u64| {
        let mut h = good.clone();
        h.truncate(HEADER_LEN);
        h[20] = op;
        h[21..29].copy_from_slice(&len.to_le_bytes());
        let arr: [u8; HEADER_LEN] = h[..HEADER_LEN].try_into().unwrap();
        decode_header(&arr)
    };
    assert!(hdr_with(OP_STATS, MAX_PAYLOAD + 1).is_err());
    assert!(hdr_with(OP_PUSH_BEGIN, MAX_PAYLOAD + 1).is_err());
    // …but the commit-log file ops keep the roomy pre-v4 cap: the same
    // length passes the header check (a logged commit holds one whole
    // coalesced gradient set).
    assert_eq!(hdr_with(OP_LOG_COMMIT, MAX_PAYLOAD + 1).unwrap().2, MAX_PAYLOAD + 1);
    assert!(hdr_with(OP_LOG_COMMIT, MAX_FILE_PAYLOAD + 1).is_err());
}

/// Hand-build chunk-op frames with hostile count fields: each cap must
/// fire in `decode_payload`, before any downstream buffer exists.
#[test]
fn hostile_chunk_fields_are_rejected_at_decode() {
    use smmf_repro::optim::blob::BlobWriter;
    let frame_with = |op: u8, payload: Vec<u8>| {
        let mut w = BlobWriter::new();
        w.bytes(protocol::MAGIC);
        w.u32(protocol::VERSION);
        w.u64(9);
        w.u8(op);
        w.u64(payload.len() as u64);
        w.bytes(&payload);
        w.finish()
    };
    let chunk_header = |total: u32, count: u64| {
        let mut p = BlobWriter::new();
        p.u32(0); // tensor_idx
        p.u32(0); // seq
        p.u32(total);
        p.u64(0); // start
        p.u64(count);
        p.u64(count); // tensor_len
        frame_with(OP_CHUNK_HEADER, p.finish())
    };
    // total = 0 and total > MAX_CHUNKS_PER_TENSOR are both refused.
    let e = decode(&chunk_header(0, 16)).unwrap_err();
    assert!(format!("{e:#}").contains("chunks"), "{e:#}");
    let e = decode(&chunk_header(MAX_CHUNKS_PER_TENSOR + 1, 16)).unwrap_err();
    assert!(format!("{e:#}").contains("chunks"), "{e:#}");
    // a chunk claiming more than CHUNK_MAX_BYTES is refused.
    let e = decode(&chunk_header(1, CHUNK_MAX_BYTES + 1)).unwrap_err();
    assert!(format!("{e:#}").contains("cap"), "{e:#}");
    // in range decodes fine.
    assert!(decode(&chunk_header(2, CHUNK_MAX_BYTES)).is_ok());

    // A ChunkData frame carrying more than CHUNK_MAX_BYTES: fits under
    // the 1 MiB frame cap, so only the per-chunk cap can catch it.
    let mut p = BlobWriter::new();
    p.u32(0);
    p.u32(0);
    p.bytes(&vec![0u8; CHUNK_MAX_BYTES as usize + 1]);
    let e = decode(&frame_with(protocol::OP_CHUNK_DATA, p.finish())).unwrap_err();
    assert!(format!("{e:#}").contains("cap"), "{e:#}");

    // PushBegin / ParamsBegin tensor-count caps.
    let mut p = BlobWriter::new();
    p.u32(0); // client
    p.u64(1); // epoch
    p.u64(1); // step
    p.u64(0); // base_step
    p.u32(u32::MAX); // n_tensors
    let e = decode(&frame_with(OP_PUSH_BEGIN, p.finish())).unwrap_err();
    assert!(format!("{e:#}").contains("cap"), "{e:#}");
    let mut p = BlobWriter::new();
    p.u64(1); // step
    p.u8(PULL_DENSE);
    p.u32(u32::MAX);
    let e = decode(&frame_with(OP_PARAMS_BEGIN, p.finish())).unwrap_err();
    assert!(format!("{e:#}").contains("cap"), "{e:#}");

    // Unknown pull mode bytes are refused on both request and reply.
    let bytes = frame_with(protocol::OP_PULL_PARAMS, {
        let mut p = BlobWriter::new();
        p.u64(0);
        p.u8(7);
        p.finish()
    });
    let e = decode(&bytes).unwrap_err();
    assert!(format!("{e:#}").contains("mode"), "{e:#}");
}

#[test]
fn trailing_payload_bytes_are_rejected() {
    // An Ack payload with one extra byte: decode_payload's finish()
    // must flag it (a desynced stream must not be silently accepted).
    let good = encode(&Frame { request_id: 2, msg: Msg::Ack { step: 3 } });
    let mut bad = good.clone();
    bad.push(0);
    // fix up the declared length to cover the extra byte
    let len = (bad.len() - HEADER_LEN) as u64;
    bad[21..29].copy_from_slice(&len.to_le_bytes());
    let e = decode(&bad).unwrap_err();
    assert!(format!("{e:#}").contains("trailing"), "{e:#}");

    // extra bytes *after* the declared payload are flagged by decode too
    let mut bad = good;
    bad.push(0);
    assert!(decode(&bad).is_err());
}

#[test]
fn string_caps_apply_to_snapshot_and_err() {
    let long = "x".repeat(protocol::MAX_STR_LEN + 1);
    // An over-long snapshot path is NOT clipped on encode (a silently
    // truncated path would be worse) — the decoder rejects the frame.
    let bytes = encode(&Frame { request_id: 1, msg: Msg::Snapshot { path: long.clone() } });
    let e = decode(&bytes).unwrap_err();
    assert!(format!("{e:#}").contains("cap"), "{e:#}");
    // An over-long Err message IS clipped on encode (char-boundary
    // safe), so an anyhow chain longer than the cap still reaches the
    // peer instead of killing the connection.
    let bytes = encode(&Frame { request_id: 1, msg: Msg::Err { msg: format!("{long}é") } });
    match decode(&bytes).unwrap().msg {
        Msg::Err { msg } => {
            assert_eq!(msg.len(), protocol::MAX_STR_LEN);
            assert!(msg.chars().all(|c| c == 'x'));
        }
        other => panic!("expected Err, got {}", other.name()),
    }
    // clipping lands on a char boundary even when a multibyte char
    // straddles the cap
    let straddle = format!("{}é tail", "x".repeat(protocol::MAX_STR_LEN - 1));
    let bytes = encode(&Frame { request_id: 1, msg: Msg::Err { msg: straddle } });
    match decode(&bytes).unwrap().msg {
        Msg::Err { msg } => assert_eq!(msg.len(), protocol::MAX_STR_LEN - 1),
        other => panic!("expected Err, got {}", other.name()),
    }
    // at the cap is fine, untouched
    let ok = "y".repeat(protocol::MAX_STR_LEN);
    let f = Frame { request_id: 1, msg: Msg::Snapshot { path: ok } };
    assert_eq!(decode(&encode(&f)).unwrap(), f);
}

#[test]
fn grads_payload_bytes_is_the_dense_yardstick() {
    // No live v4 frame carries a whole gradient set, but the function
    // remains the honest dense-wire baseline: fixed push header fields
    // plus a u64 length prefix + 4 bytes per element per tensor.
    let shapes = vec![vec![3, 2], vec![7], vec![1]];
    let expect: u64 = (4 + 8 + 8 + 8 + 4) + (8 + 4 * 6) + (8 + 4 * 7) + (8 + 4 * 1);
    assert_eq!(protocol::grads_payload_bytes(&shapes), expect);
    // and the x64 scaled inventory really is past the connection cap —
    // the premise of the chunked-streaming e2e pins.
    let inv = smmf_repro::models::registry::inventory_by_name("tiny_lm_x64").unwrap();
    assert!(protocol::grads_payload_bytes(&inv.shapes()) > MAX_PAYLOAD);
}

#[test]
fn chunk_plan_is_deterministic_row_aligned_and_total() {
    // Plans tile the tensor exactly, in order, within budget.
    for (len, row, budget) in
        [(0u64, 0u64, 1024u64), (10, 0, 3), (4096, 16, 100), (590_000, 4, CHUNK_MAX_BYTES)]
    {
        let plan = chunk_plan(len, row, budget);
        assert!(!plan.is_empty());
        let mut cursor = 0;
        for &(start, count) in &plan {
            assert_eq!(start, cursor, "({len},{row},{budget})");
            assert!(count <= budget.max(1));
            cursor += count;
        }
        assert_eq!(cursor, len);
        // deterministic: both peers derive identical spans
        assert_eq!(plan, chunk_plan(len, row, budget));
    }
    // row alignment: every non-final chunk covers whole rows
    let plan = chunk_plan(4096, 16, 100);
    for &(_, count) in &plan[..plan.len() - 1] {
        assert_eq!(count % 16, 0);
    }
    // zero-length tensors still occupy one (0, 0) chunk
    assert_eq!(chunk_plan(0, 4, 1024), vec![(0, 0)]);
}

#[test]
fn assembler_round_trips_any_arrival_order_with_resend() {
    // Stream two tensors out of order, drop one chunk, recover it via
    // missing() — the Resend driver — then finish exactly.
    let data: Vec<Vec<u8>> = vec![(0u8..=255).cycle().take(700).collect(), Vec::new()];
    let lens: Vec<u64> = data.iter().map(|d| d.len() as u64).collect();
    let mut asm = ChunkAssembler::for_lens(&lens);
    let plan = chunk_plan(lens[0], 4, 256);
    let total = plan.len() as u32;
    // send all of tensor 0's chunks in reverse, skipping seq 1
    for (seq, &(start, count)) in plan.iter().enumerate().rev() {
        if seq == 1 {
            continue;
        }
        asm.header(0, seq as u32, total, start, count, lens[0]).unwrap();
        asm.data(0, seq as u32, &data[0][start as usize..(start + count) as usize]).unwrap();
    }
    asm.header(1, 0, 1, 0, 0, 0).unwrap();
    asm.data(1, 0, &[]).unwrap();
    assert!(!asm.is_complete());
    assert_eq!(asm.missing(), Some((0, 1)));
    let (start, count) = plan[1];
    asm.header(0, 1, total, start, count, lens[0]).unwrap();
    asm.data(0, 1, &data[0][start as usize..(start + count) as usize]).unwrap();
    assert!(asm.is_complete());
    assert_eq!(asm.missing(), None);
    assert_eq!(asm.finish().unwrap(), data);
}

#[test]
fn assembler_rejects_duplicates_overlaps_and_bounds_with_typed_errors() {
    let mut asm = ChunkAssembler::for_lens(&[100]);
    asm.header(0, 0, 2, 0, 60, 100).unwrap();
    // duplicate header
    assert_eq!(asm.header(0, 0, 2, 0, 60, 100), Err(ChunkError::Duplicate { tensor_idx: 0, seq: 0 }));
    // overlapping span
    assert_eq!(asm.header(0, 1, 2, 40, 60, 100), Err(ChunkError::Overlap { tensor_idx: 0, seq: 1 }));
    // out-of-bounds range
    assert_eq!(
        asm.header(0, 1, 2, 60, 60, 100),
        Err(ChunkError::RangeOutOfBounds { tensor_idx: 0, seq: 1 })
    );
    // contradicting total
    assert_eq!(
        asm.header(0, 1, 3, 60, 40, 100),
        Err(ChunkError::TotalMismatch { tensor_idx: 0, got: 3, expected: 2 })
    );
    // tensor out of range
    assert_eq!(
        asm.header(1, 0, 1, 0, 0, 0),
        Err(ChunkError::TensorOutOfRange { tensor_idx: 1, n_tensors: 1 })
    );
    // data without header / size mismatch
    assert_eq!(
        asm.data(0, 1, &[0; 40]),
        Err(ChunkError::DataWithoutHeader { tensor_idx: 0, seq: 1 })
    );
    assert_eq!(
        asm.data(0, 0, &[0; 10]),
        Err(ChunkError::DataSizeMismatch { tensor_idx: 0, seq: 0, got: 10, expected: 60 })
    );
    // finishing with a chunk outstanding is Missing, typed
    asm.data(0, 0, &[7; 60]).unwrap();
    assert_eq!(asm.finish(), Err(ChunkError::Missing { tensor_idx: 0, seq: 1 }));

    // untrusted mode caps the announced length
    let mut asm = ChunkAssembler::for_unknown(1, 1 << 10);
    assert_eq!(
        asm.header(0, 0, 1, 0, 16, 1 << 20),
        Err(ChunkError::LenMismatch { tensor_idx: 0, got: 1 << 20, expected: 1 << 10 })
    );
}

#[test]
fn prop_random_corruption_never_panics() {
    // Flip random bytes of random valid frames: decoding must always
    // return (Ok for the rare no-op flip of f32 payload bytes, Err
    // otherwise) — never panic, never hang, never over-allocate.
    let ops = all_ops();
    prop::cases(200, |rng| {
        let frame = Frame {
            request_id: rng.next_u64(),
            msg: ops[rng.below(ops.len())].clone(),
        };
        let mut bytes = encode(&frame);
        let flips = 1 + rng.below(4);
        for _ in 0..flips {
            let i = rng.below(bytes.len());
            bytes[i] ^= 1u8 << rng.below(8);
        }
        let _ = decode(&bytes);
        // truncate at a random point too
        let cut = rng.below(bytes.len() + 1);
        let _ = decode(&bytes[..cut]);
    });
}
