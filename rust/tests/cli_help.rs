//! `repro help` drift guard: cross-checks the `HELP` text in
//! `src/main.rs` against the `match cmd.as_str()` dispatch arms, so a
//! new subcommand cannot land without a help entry (and a help entry
//! cannot outlive its command). `main.rs` is a binary root, so the test
//! reads the source directly — the strings under test are compile-time
//! constants of that file.

use std::collections::BTreeSet;

const MAIN_RS: &str = include_str!("../src/main.rs");

/// The subcommand literals of the dispatch `match` in `run()`.
fn dispatch_commands() -> BTreeSet<String> {
    let start = MAIN_RS
        .find("match cmd.as_str()")
        .expect("main.rs dispatches on `match cmd.as_str()`");
    let end = MAIN_RS[start..]
        .find("other => bail!")
        .map(|i| start + i)
        .expect("dispatch match ends with a catch-all arm");
    let block = &MAIN_RS[start..end];
    let mut out = BTreeSet::new();
    for line in block.lines() {
        let line = line.trim();
        // Arms look like `"name" => …` or `"a" | "b" => …`.
        let Some((pattern, _)) = line.split_once("=>") else { continue };
        for alt in pattern.split('|') {
            let alt = alt.trim();
            if let Some(stripped) = alt.strip_prefix('"').and_then(|s| s.strip_suffix('"')) {
                // `--help` is an alias of `help`, not its own command.
                if !stripped.starts_with("--") {
                    out.insert(stripped.to_string());
                }
            }
        }
    }
    assert!(!out.is_empty(), "found no dispatch arms");
    out
}

/// The command tokens of the HELP text's `commands:` block.
fn help_commands() -> BTreeSet<String> {
    let start = MAIN_RS.find("const HELP: &str = \"").expect("main.rs defines HELP");
    let body = &MAIN_RS[start..];
    let end = body.find("\";").expect("HELP is a terminated string literal");
    let help = &body[..end];
    let commands_at = help.find("commands:").expect("HELP has a commands: section");
    let mut out = BTreeSet::new();
    for line in help[commands_at..].lines().skip(1) {
        if line.starts_with("common flags:") {
            break;
        }
        // Command rows are indented exactly two spaces; continuation
        // rows are indented further.
        let Some(rest) = line.strip_prefix("  ") else { continue };
        if rest.starts_with(' ') {
            continue;
        }
        let token = rest.split_whitespace().next().unwrap_or("");
        for alt in token.split('|') {
            if !alt.is_empty() {
                out.insert(alt.to_string());
            }
        }
    }
    assert!(!out.is_empty(), "found no help command rows");
    out
}

#[test]
fn help_lists_exactly_the_live_subcommands() {
    let arms = dispatch_commands();
    let mut help = help_commands();

    // `tableN` in the help maps onto the `t.starts_with("table")` guard
    // arm in the dispatch (table1..table13 shortcuts).
    assert!(
        help.remove("tableN"),
        "help must document the tableN shortcuts: {help:?}"
    );
    assert!(
        MAIN_RS.contains("starts_with(\"table\")"),
        "the tableN guard arm disappeared from main.rs — update HELP"
    );

    let undocumented: Vec<_> = arms.difference(&help).collect();
    assert!(
        undocumented.is_empty(),
        "subcommands missing from `repro help`: {undocumented:?}"
    );
    let stale: Vec<_> = help.difference(&arms).collect();
    assert!(
        stale.is_empty(),
        "`repro help` documents commands with no dispatch arm: {stale:?}"
    );

    // The commands this repo's docs and Makefile lean on must all be
    // live (regression guard for the original help-drift bug).
    for cmd in [
        "help", "list", "table5", "suite", "worker", "report", "dp", "fused", "ablate", "serve",
        "loadgen", "trace",
    ] {
        assert!(arms.contains(cmd), "dispatch lost `{cmd}`");
    }
}

const OBS_RS: &str = include_str!("../src/obs/mod.rs");

/// The observability flags are parsed in `obs::ObsConfig` but documented
/// in `main.rs`'s HELP — pin the two files to each other so neither a
/// renamed flag nor a deleted help line can drift silently.
#[test]
fn help_documents_exactly_the_obs_flags_the_parser_reads() {
    let start = MAIN_RS.find("const HELP: &str = \"").expect("main.rs defines HELP");
    let body = &MAIN_RS[start..];
    let help = &body[..body.find("\";").expect("HELP is terminated")];

    for (accessor, flag) in [
        ("has_flag(\"trace\")", "--trace"),
        ("has_flag(\"metrics\")", "--metrics"),
        ("opt(\"trace-out\")", "--trace-out"),
        ("opt(\"metrics-out\")", "--metrics-out"),
    ] {
        assert!(
            OBS_RS.contains(accessor),
            "obs/mod.rs no longer parses {accessor} — update this guard and HELP"
        );
        assert!(
            help.contains(flag),
            "`repro help` does not document the {flag} flag"
        );
    }

    // The `[obs]` config keys layered under the flags must stay in sync
    // with the parser too.
    for key in ["obs.trace", "obs.metrics", "obs.trace_path", "obs.metrics_path"] {
        assert!(OBS_RS.contains(&format!("\"{key}\"")), "obs/mod.rs lost the {key} config key");
    }

    // The trace wrapper's help row must mention the artifact it writes.
    assert!(
        help.contains("trace -- CMD"),
        "HELP lost the `trace -- CMD` row"
    );
}
