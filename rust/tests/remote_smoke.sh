#!/usr/bin/env bash
# Distributed-suite smoke, run by `make remote-smoke`.
#
# Spawns two real `repro worker` processes on loopback ephemeral ports,
# dispatches the smoke suite to them, and asserts the tentpole contract
# from the CLI:
#
#   1. the dispatched run completes every cell on the remote workers;
#   2. a second dispatched invocation skips every cell (the re-entry
#      cache) and re-renders byte-identical reports;
#   3. a local-pool invocation over the same suite dir also skips every
#      cell and renders the same bytes — the backend is invisible in
#      the artifacts.
#
#   bash rust/tests/remote_smoke.sh      # from the repo root
#   make remote-smoke                    # equivalent
set -euo pipefail

cd "$(dirname "$0")/.."   # rust/

echo "== cargo build --release =="
cargo build --release
REPRO=target/release/repro

OUT=target/remote-smoke
rm -rf "$OUT"
mkdir -p "$OUT"

# Workers bind ephemeral ports and print them; artifacts go under the
# shared (relative) out dir because coordinator and workers share this
# cwd. Kill both on any exit.
"$REPRO" worker --listen 127.0.0.1:0 --capacity 2 >"$OUT/worker1.log" 2>&1 &
W1=$!
"$REPRO" worker --listen 127.0.0.1:0 --capacity 2 >"$OUT/worker2.log" 2>&1 &
W2=$!
trap 'kill "$W1" "$W2" 2>/dev/null || true' EXIT

addr_of() { # addr_of <log> -> HOST:PORT, retrying until the worker prints it
  local log=$1 addr="" i
  for i in $(seq 1 100); do
    addr=$(sed -n 's/^\[worker\] listening on \([0-9.:]*\).*$/\1/p' "$log" | head -n1)
    [ -n "$addr" ] && { echo "$addr"; return 0; }
    sleep 0.1
  done
  echo "worker never printed its address ($log):" >&2
  cat "$log" >&2
  return 1
}
A1=$(addr_of "$OUT/worker1.log")
A2=$(addr_of "$OUT/worker2.log")
echo "== workers up: $A1, $A2 =="

echo "== dispatched suite (remote:$A1,$A2) =="
"$REPRO" suite tests/suite_smoke.toml \
  --out-dir "$OUT" --workers "remote:$A1,$A2" --lease-timeout-ms 5000 \
  --docs "$OUT/RESULTS.remote.md" --bench-json "$OUT/BENCH.remote.json" \
  | tee "$OUT/run1.log"
grep -q "dispatched to worker" "$OUT/run1.log" || {
  echo "no cell was dispatched to a remote worker"; exit 1; }

echo "== dispatched again: every cell must be cached =="
"$REPRO" suite tests/suite_smoke.toml \
  --out-dir "$OUT" --workers "remote:$A1,$A2" --lease-timeout-ms 5000 \
  --docs "$OUT/RESULTS.remote2.md" --bench-json "$OUT/BENCH.remote2.json" \
  | tee "$OUT/run2.log"
grep -q " 0 ran, 4 cached, 0 failed" "$OUT/run2.log" || {
  echo "re-entry cache miss: expected all 4 cells cached"; exit 1; }
cmp "$OUT/RESULTS.remote.md" "$OUT/RESULTS.remote2.md"
cmp "$OUT/BENCH.remote.json" "$OUT/BENCH.remote2.json"

echo "== local pool over the same suite dir: same bytes =="
"$REPRO" suite tests/suite_smoke.toml \
  --out-dir "$OUT" --workers 2 \
  --docs "$OUT/RESULTS.local.md" --bench-json "$OUT/BENCH.local.json" \
  | tee "$OUT/run3.log"
grep -q " 0 ran, 4 cached, 0 failed" "$OUT/run3.log" || {
  echo "cross-backend cache miss: expected all 4 cells cached"; exit 1; }
cmp "$OUT/RESULTS.remote.md" "$OUT/RESULTS.local.md"
cmp "$OUT/BENCH.remote.json" "$OUT/BENCH.local.json"

echo "remote-smoke OK (reports byte-identical across backends)"
