//! End-to-end tests for the optimizer-state server: the determinism
//! contract (a K-shard server driven by N concurrent TCP clients writes
//! a snapshot byte-identical to the equivalent single-process trainer,
//! at shards {1,2} × clients {1,4}), the loadgen measurements, the
//! wire-level error paths, and the fault-tolerance contract (membership
//! epochs, client eviction, shard crash-resume, snapshot resume with
//! re-sharding) pinned against the elastic reference trainer.
//!
//! Everything here runs over real loopback TCP against the `tiny_lm`
//! inventory (~15K params) — no AOT artifacts, no PJRT — plus the
//! `tiny_lm_x8` / `tiny_lm_x64` scaled variants that pin the v4 chunk
//! streaming: `tiny_lm_x64`'s dense gradient set does not fit one wire
//! frame, so every cell it passes is evidence the chunk path (not a
//! big-frame fallback) carried the run.

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};

use smmf_repro::coordinator::ExperimentConfig;
use smmf_repro::models::inventory_by_name;
use smmf_repro::optim::OptKind;
use smmf_repro::server::protocol::{grads_payload_bytes, NO_CLIENT, PULL_DENSE};
use smmf_repro::server::{
    reference_checkpoint, reference_checkpoint_elastic, run_loadgen, Client, LoadgenOptions, Msg,
    PushOutcome, ServeOptions, Server, TensorMoments, MAX_PAYLOAD,
};
use smmf_repro::train::checkpoint;

fn tmp(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("smmf_server_{tag}_{}.bin", std::process::id()))
}

/// A full-shape all-zero gradient set — the smallest push the v4
/// stream layer forwards to the coordinator (a wrong tensor *count* is
/// rejected at the connection handler, before membership or step
/// validation ever runs).
fn zero_grads(shapes: &[Vec<usize>]) -> Vec<Vec<f32>> {
    shapes.iter().map(|s| vec![0.0f32; s.iter().product()]).collect()
}

fn test_config(kind: OptKind) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.optimizer = kind;
    cfg.optim = smmf_repro::optim::OptimConfig::paper_defaults(kind);
    cfg.optim.lr = 0.05;
    cfg.seed = 3;
    cfg
}

fn serve_opts(shards: usize, clients: usize) -> ServeOptions {
    ServeOptions {
        addr: "127.0.0.1:0".into(),
        model: "synthetic:tiny_lm".into(),
        shards,
        clients,
        max_pending: 64,
        ..ServeOptions::default()
    }
}

/// The acceptance matrix: shards {1,2} × clients {1,4}, snapshot
/// bit-identity against the single-process reference trainer.
#[test]
fn sharded_concurrent_snapshot_is_bit_identical_to_reference() {
    let steps = 12u64;
    let shapes = inventory_by_name("tiny_lm").unwrap().shapes();
    for kind in [OptKind::Smmf, OptKind::Adam] {
        let cfg = test_config(kind);
        for shards in [1usize, 2] {
            for clients in [1usize, 4] {
                let tag = format!("{}_{shards}s_{clients}c", kind.name());
                let snap = tmp(&tag);
                let refp = tmp(&format!("{tag}_ref"));

                let server = Server::start(&cfg, &serve_opts(shards, clients)).unwrap();
                let addr = server.addr.to_string();
                let report =
                    run_loadgen(
                        &addr,
                        &shapes,
                        cfg.seed,
                        &LoadgenOptions { clients, steps, ..LoadgenOptions::default() },
                    )
                    .unwrap();
                let mut ctl = Client::connect(&addr).unwrap();
                let bytes = ctl.snapshot(snap.to_str().unwrap()).unwrap();
                let stats = ctl.stats().unwrap();
                ctl.shutdown().unwrap();
                let final_stats = server.wait().unwrap();

                assert_eq!(stats.step, steps, "{tag}");
                assert_eq!(stats.pushes, clients as u64 * steps, "{tag}");
                assert_eq!(final_stats.snapshots, 1, "{tag}");
                assert_eq!(report.pushes, clients as u64 * steps, "{tag}");

                let ref_loss =
                    reference_checkpoint(&cfg, "synthetic:tiny_lm", clients, steps, &refp)
                        .unwrap();
                let got = std::fs::read(&snap).unwrap();
                let want = std::fs::read(&refp).unwrap();
                assert_eq!(got.len() as u64, bytes, "{tag}: SnapshotDone size");
                assert!(got == want, "{tag}: snapshot differs from the reference");
                // the client-observed objective matches the reference's
                assert_eq!(report.final_loss.to_bits(), ref_loss.to_bits(), "{tag}");
                // the well actually converges (sanity that training ran)
                assert!(report.final_loss < 0.125, "{tag}: loss {}", report.final_loss);

                // A snapshot is a regular SMMFCKPT v2 file with the full
                // section set.
                let ck = checkpoint::load_any(&snap).unwrap();
                assert_eq!(ck.step, steps, "{tag}");
                assert_eq!(ck.opt.as_ref().unwrap().kind, kind, "{tag}");
                assert!(ck.schedule.is_some() && ck.config.is_some(), "{tag}");

                std::fs::remove_file(&snap).ok();
                std::fs::remove_file(&refp).ok();
            }
        }
    }
}

/// Sharding is invisible in the bits: the same run on 1 vs 2 shards
/// produces identical snapshots (both already equal the reference; this
/// pins the transitive property directly as well).
#[test]
fn shard_count_does_not_change_the_snapshot() {
    let steps = 8u64;
    let cfg = test_config(OptKind::Smmf);
    let shapes = inventory_by_name("tiny_lm").unwrap().shapes();
    let mut files = Vec::new();
    for shards in [1usize, 2] {
        let snap = tmp(&format!("shardcmp_{shards}"));
        let server = Server::start(&cfg, &serve_opts(shards, 2)).unwrap();
        let addr = server.addr.to_string();
        run_loadgen(
            &addr,
            &shapes,
            cfg.seed,
            &LoadgenOptions { clients: 2, steps, ..LoadgenOptions::default() },
        )
        .unwrap();
        let mut ctl = Client::connect(&addr).unwrap();
        ctl.snapshot(snap.to_str().unwrap()).unwrap();
        ctl.shutdown().unwrap();
        server.wait().unwrap();
        files.push(std::fs::read(&snap).unwrap());
        std::fs::remove_file(&snap).ok();
    }
    assert!(files[0] == files[1], "1-shard vs 2-shard snapshots differ");
}

#[test]
fn loadgen_reports_finite_latencies_and_throughput() {
    let cfg = test_config(OptKind::Smmf);
    let shapes = inventory_by_name("tiny_lm").unwrap().shapes();
    let server = Server::start(&cfg, &serve_opts(2, 3)).unwrap();
    let addr = server.addr.to_string();
    let report = run_loadgen(
        &addr,
        &shapes,
        cfg.seed,
        &LoadgenOptions { clients: 3, steps: 6, ..LoadgenOptions::default() },
    )
    .unwrap();
    Client::connect(&addr).unwrap().shutdown().unwrap();
    server.wait().unwrap();
    assert_eq!(report.clients, 3);
    assert_eq!(report.steps, 6);
    assert!(report.steps_per_s > 0.0, "{report:?}");
    assert!(report.push_p50_ms.is_finite() && report.push_p50_ms >= 0.0, "{report:?}");
    assert!(report.push_p99_ms >= report.push_p50_ms, "{report:?}");
    assert!(report.push_mean_ms.is_finite(), "{report:?}");
    assert!(report.elapsed_s > 0.0);
}

/// Wire-level error paths: bad pushes are rejected with Err (not a
/// hang, not a dropped connection), replies are not accepted as
/// requests, and the connection survives to serve further requests.
#[test]
fn server_rejects_bad_requests_and_keeps_serving() {
    let cfg = test_config(OptKind::Smmf);
    let server = Server::start(&cfg, &serve_opts(1, 2)).unwrap();
    let addr = server.addr.to_string();
    let mut c = Client::connect(&addr).unwrap();
    let shapes = inventory_by_name("tiny_lm").unwrap().shapes();
    let grads = zero_grads(&shapes);

    // unknown client id
    let out = c.push_grad(9, 1, 1, 0, grads.clone()).unwrap();
    assert!(matches!(out, PushOutcome::Rejected(_)), "{out:?}");
    // wrong step
    let out = c.push_grad(0, 1, 5, 4, grads.clone()).unwrap();
    assert!(matches!(out, PushOutcome::Rejected(_)), "{out:?}");
    // a base_step that is not step - 1 on the synchronous path
    match c.push_grad(0, 1, 1, 7, grads.clone()).unwrap() {
        PushOutcome::Rejected(msg) => assert!(msg.contains("base_step"), "{msg}"),
        other => panic!("expected Rejected, got {other:?}"),
    }
    // wrong tensor count (right client, right step): refused by the
    // stream layer itself, before the batcher is consulted
    match c.push_grad(0, 1, 1, 0, vec![vec![1.0]]).unwrap() {
        PushOutcome::Rejected(msg) => assert!(msg.contains("tensors"), "{msg}"),
        other => panic!("expected Rejected, got {other:?}"),
    }
    // a pull floor the server cannot honor gets the typed TooStale reply
    let reply = c.call(Msg::PullParams { min_step: 50, mode: PULL_DENSE }).unwrap();
    assert_eq!(reply, Msg::TooStale { applied: 0, required: 50 });
    // a reply op sent as a request is rejected by the handler
    let reply = c.call(Msg::Ack { step: 1 }).unwrap();
    assert!(matches!(reply, Msg::Err { .. }), "{}", reply.name());
    // chunk frames with no enclosing PushBegin stream are not requests
    let reply = c
        .call(Msg::ChunkHeader { tensor_idx: 0, seq: 0, total: 1, start: 0, count: 4, tensor_len: 4 })
        .unwrap();
    assert!(matches!(reply, Msg::Err { .. }), "{}", reply.name());
    // a Resend with no pull reply cached on this connection is an error
    let reply = c.call(Msg::Resend { tensor_idx: 0, seq: 0 }).unwrap();
    match reply {
        Msg::Err { ref msg } => assert!(msg.contains("resend") || msg.contains("pull"), "{msg}"),
        other => panic!("expected Err, got {}", other.name()),
    }
    // snapshot to an unwritable path errors instead of killing the server
    let reply = c.call(Msg::Snapshot { path: "/definitely/not/a/dir/x.bin".into() }).unwrap();
    assert!(matches!(reply, Msg::Err { .. }), "{}", reply.name());

    // a loadgen whose client count disagrees with the server's barrier
    // width fails loudly up front instead of deadlocking the barrier
    // (after the membership-settle poll runs out — nobody else joins)
    let e = run_loadgen(
        &addr,
        &shapes,
        cfg.seed,
        &LoadgenOptions { clients: 1, steps: 1, ..LoadgenOptions::default() },
    )
    .unwrap_err();
    assert!(format!("{e:#}").contains("barrier"), "{e:#}");

    // …and the same connection still works
    let (step, tensors) = c.pull_params().unwrap();
    assert_eq!(step, 0);
    assert_eq!(tensors.len(), inventory_by_name("tiny_lm").unwrap().tensors.len());
    let stats = c.stats().unwrap();
    assert_eq!(stats.step, 0);
    assert_eq!((stats.shards, stats.clients), (1, 2));
    c.shutdown().unwrap();
    server.wait().unwrap();
}

/// Epoch handling on the wire: a push tagged with a non-current
/// membership epoch gets the typed `StaleEpoch` reply (carrying the
/// current epoch) before any other validation, and the typed client
/// surfaces it as `PushOutcome::Stale` instead of an error string.
#[test]
fn stale_epoch_pushes_get_a_typed_reply() {
    let cfg = test_config(OptKind::Smmf);
    let server = Server::start(&cfg, &serve_opts(1, 2)).unwrap();
    let addr = server.addr.to_string();
    let mut c = Client::connect(&addr).unwrap();
    let shapes = inventory_by_name("tiny_lm").unwrap().shapes();

    let view = c.epoch_info().unwrap();
    assert_eq!((view.epoch, view.next_step, view.client), (1, 1, NO_CLIENT));
    assert_eq!(view.members, vec![0, 1]);

    let out = c.push_grad(0, 7, 1, 0, zero_grads(&shapes)).unwrap();
    assert_eq!(out, PushOutcome::Stale(1));
    let out = c.push_grad(0, 99, 1, 0, zero_grads(&shapes)).unwrap();
    assert_eq!(out, PushOutcome::Stale(1));

    c.shutdown().unwrap();
    server.wait().unwrap();
}

/// Polite membership: `Join` assigns a fresh id and widens the barrier,
/// `Leave` narrows it, each bumping the epoch — and a run after the
/// churn is bit-identical to one on a server that never saw it (the
/// epoch counter moved, the optimizer state did not).
#[test]
fn join_and_leave_bump_the_epoch_and_renegotiate_the_barrier() {
    let steps = 3u64;
    let cfg = test_config(OptKind::Smmf);
    let shapes = inventory_by_name("tiny_lm").unwrap().shapes();
    let snap = tmp("member");
    let refp = tmp("member_ref");
    let server = Server::start(&cfg, &serve_opts(1, 1)).unwrap();
    let addr = server.addr.to_string();
    let mut c = Client::connect(&addr).unwrap();

    let joined = c.join().unwrap();
    assert_eq!((joined.epoch, joined.client), (2, 1));
    assert_eq!(joined.members, vec![0, 1]);
    assert_eq!(c.stats().unwrap().clients, 2, "barrier width follows the membership");

    // leaving as a non-member is a clean rejection, not a state change
    assert!(c.leave(17).is_err());

    let left = c.leave(1).unwrap();
    assert_eq!(left.epoch, 3);
    assert_eq!(left.members, vec![0]);
    assert_eq!(c.stats().unwrap().clients, 1);

    let report = run_loadgen(
        &addr,
        &shapes,
        cfg.seed,
        &LoadgenOptions { clients: 1, steps, ..LoadgenOptions::default() },
    )
    .unwrap();
    let mut ctl = Client::connect(&addr).unwrap();
    ctl.snapshot(snap.to_str().unwrap()).unwrap();
    ctl.shutdown().unwrap();
    server.wait().unwrap();

    let ref_loss = reference_checkpoint(&cfg, "synthetic:tiny_lm", 1, steps, &refp).unwrap();
    assert_eq!(report.final_loss.to_bits(), ref_loss.to_bits());
    let got = std::fs::read(&snap).unwrap();
    let want = std::fs::read(&refp).unwrap();
    assert!(got == want, "post-churn snapshot differs from the fixed-membership reference");
    std::fs::remove_file(&snap).ok();
    std::fs::remove_file(&refp).ok();
}

/// The chaos contract (the acceptance test): one client crashes mid-run
/// (silent stop, evicted at the next step boundary) and one shard
/// worker is killed mid-run (respawned from the recovery image, the
/// interrupted step replayed) — and the final snapshot is still
/// bit-identical to the elastic reference trainer run over the
/// surviving epoch schedule.
#[test]
fn chaos_kill_shard_and_drop_client_stay_bit_identical() {
    let steps = 10u64;
    let drop_at = 4u64;
    let cfg = test_config(OptKind::Smmf);
    let shapes = inventory_by_name("tiny_lm").unwrap().shapes();
    let snap = tmp("chaos");
    let refp = tmp("chaos_ref");

    // Generous deadline: the survivors push within microseconds of each
    // other, but a descheduled test thread must never look like a crash.
    let opts = ServeOptions { client_timeout_ms: 400, resilient: true, ..serve_opts(2, 3) };
    let server = Server::start(&cfg, &opts).unwrap();
    let addr = server.addr.to_string();

    let done = AtomicBool::new(false);
    let report = std::thread::scope(|s| {
        // Kill shard 0 once the run reaches the drop step: the barrier
        // then stalls for client_timeout_ms waiting to evict the dropped
        // client, so the kill deterministically lands mid-run, before
        // the first survivors-only step is applied.
        s.spawn(|| {
            let mut probe = Client::connect(&addr).unwrap();
            while !done.load(Ordering::SeqCst) {
                if probe.stats().unwrap().step >= drop_at {
                    server.kill_shard(0);
                    return;
                }
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
        });
        let report = run_loadgen(
            &addr,
            &shapes,
            cfg.seed,
            &LoadgenOptions {
                clients: 3,
                steps,
                drop_client_at: drop_at,
                ..LoadgenOptions::default()
            },
        )
        .unwrap();
        done.store(true, Ordering::SeqCst);
        report
    });

    let mut ctl = Client::connect(&addr).unwrap();
    let bytes = ctl.snapshot(snap.to_str().unwrap()).unwrap();
    let stats = ctl.stats().unwrap();
    ctl.shutdown().unwrap();
    server.wait().unwrap();

    assert_eq!(stats.step, steps, "{stats:?}");
    assert_eq!(stats.evictions, 1, "{stats:?}");
    assert!(stats.respawns >= 1, "{stats:?}");
    assert_eq!(stats.epoch, 2, "{stats:?}");
    // The crash is silent — the dropped client never *observes* its
    // eviction, so the server-side counter above is the witness.
    assert_eq!(report.evicted, 0, "{report:?}");
    // 3 members for steps 1..=drop, the 2 survivors for the rest.
    assert_eq!(report.pushes, 3 * drop_at + 2 * (steps - drop_at), "{report:?}");

    let ref_loss = reference_checkpoint_elastic(
        &cfg,
        "synthetic:tiny_lm",
        &[(1, vec![0, 1, 2]), (drop_at + 1, vec![0, 1])],
        steps,
        &refp,
    )
    .unwrap();
    let got = std::fs::read(&snap).unwrap();
    let want = std::fs::read(&refp).unwrap();
    assert_eq!(got.len() as u64, bytes, "SnapshotDone size");
    assert!(got == want, "chaos snapshot differs from the elastic reference");
    assert_eq!(report.final_loss.to_bits(), ref_loss.to_bits());

    std::fs::remove_file(&snap).ok();
    std::fs::remove_file(&refp).ok();
}

/// `--resume`: a snapshot taken mid-run restarts a server — on a
/// *different* shard count — and the continuation is bit-identical to
/// the uninterrupted run. State migrates over the checkpoint path and
/// the FLOP-balancing planner re-partitions onto the new K.
#[test]
fn resume_on_a_different_shard_count_continues_bit_identically() {
    let (first, rest) = (5u64, 5u64);
    let cfg = test_config(OptKind::Smmf);
    let shapes = inventory_by_name("tiny_lm").unwrap().shapes();
    let mid = tmp("resume_mid");
    let fin = tmp("resume_fin");
    let refp = tmp("resume_ref");

    // Phase A: 1 shard, stop after `first` steps, snapshot, shut down.
    let server = Server::start(&cfg, &serve_opts(1, 2)).unwrap();
    let addr = server.addr.to_string();
    run_loadgen(
        &addr,
        &shapes,
        cfg.seed,
        &LoadgenOptions { clients: 2, steps: first, ..LoadgenOptions::default() },
    )
    .unwrap();
    let mut ctl = Client::connect(&addr).unwrap();
    ctl.snapshot(mid.to_str().unwrap()).unwrap();
    ctl.shutdown().unwrap();
    server.wait().unwrap();

    // Phase B: resume the snapshot onto 2 shards, drive the rest.
    let opts =
        ServeOptions { resume: Some(mid.to_str().unwrap().into()), ..serve_opts(2, 2) };
    let server = Server::start(&cfg, &opts).unwrap();
    let addr = server.addr.to_string();
    let mut ctl = Client::connect(&addr).unwrap();
    assert_eq!(ctl.stats().unwrap().step, first, "resume restores the step counter");
    let report = run_loadgen(
        &addr,
        &shapes,
        cfg.seed,
        &LoadgenOptions {
            clients: 2,
            steps: rest,
            start_step: first + 1,
            ..LoadgenOptions::default()
        },
    )
    .unwrap();
    ctl.snapshot(fin.to_str().unwrap()).unwrap();
    ctl.shutdown().unwrap();
    server.wait().unwrap();

    let ref_loss =
        reference_checkpoint(&cfg, "synthetic:tiny_lm", 2, first + rest, &refp).unwrap();
    assert_eq!(report.final_loss.to_bits(), ref_loss.to_bits());
    let got = std::fs::read(&fin).unwrap();
    let want = std::fs::read(&refp).unwrap();
    assert!(got == want, "resumed continuation differs from the uninterrupted reference");
    for p in [&mid, &fin, &refp] {
        std::fs::remove_file(p).ok();
    }
}

/// Regression pin for the async refactor (satellite of the
/// bounded-staleness PR): a server started with an *explicit*
/// `staleness: 0` takes the same synchronous-barrier code path as the
/// default, and both stay bit-identical to the single-process
/// reference. If the `Ingest` dispatch ever perturbs sync-mode bits,
/// this fails before any async test runs.
#[test]
fn staleness_zero_is_bit_identical_to_the_barrier_path() {
    let steps = 8u64;
    let cfg = test_config(OptKind::Smmf);
    let shapes = inventory_by_name("tiny_lm").unwrap().shapes();
    let refp = tmp("szero_ref");
    let mut files = Vec::new();

    for (tag, explicit) in [("default", false), ("explicit", true)] {
        let snap = tmp(&format!("szero_{tag}"));
        let mut opts = serve_opts(2, 2);
        if explicit {
            opts.staleness = 0;
        }
        let server = Server::start(&cfg, &opts).unwrap();
        let addr = server.addr.to_string();
        run_loadgen(
            &addr,
            &shapes,
            cfg.seed,
            &LoadgenOptions { clients: 2, steps, ..LoadgenOptions::default() },
        )
        .unwrap();
        let mut ctl = Client::connect(&addr).unwrap();
        let stats = ctl.stats().unwrap();
        assert_eq!(stats.staleness, 0, "{tag}: sync server advertises staleness 0");
        ctl.snapshot(snap.to_str().unwrap()).unwrap();
        ctl.shutdown().unwrap();
        server.wait().unwrap();
        files.push(std::fs::read(&snap).unwrap());
        std::fs::remove_file(&snap).ok();
    }
    assert!(files[0] == files[1], "explicit staleness=0 changed the snapshot bits");

    reference_checkpoint(&cfg, "synthetic:tiny_lm", 2, steps, &refp).unwrap();
    let want = std::fs::read(&refp).unwrap();
    assert!(files[0] == want, "staleness=0 snapshot differs from the reference");
    std::fs::remove_file(&refp).ok();
}

/// The paper-scale differential pin (the v4 acceptance test): the same
/// run at 1×, 8× and 64× vocab scales, across shards {1,2} × clients
/// {1,4}, each snapshot byte-identical to the single-process dense
/// reference. `tiny_lm_x64`'s dense gradient set exceeds the connection
/// payload cap — under v3 the server refused to even start on it; here
/// it streams chunk-by-chunk and the *streamed* snapshot writer's
/// output is compared byte-for-byte against the reference's dense
/// writer, pinning streamed == dense end to end.
#[test]
fn scaled_inventories_stream_bit_identically_to_reference() {
    let steps = 3u64;
    let cfg = test_config(OptKind::Smmf);
    for scale in [1usize, 8, 64] {
        let model =
            if scale == 1 { "tiny_lm".to_string() } else { format!("tiny_lm_x{scale}") };
        let spec = format!("synthetic:{model}");
        let shapes = inventory_by_name(&model).unwrap().shapes();
        if scale == 64 {
            // The point of the exercise: one dense push no longer fits
            // a connection frame, so only chunking can carry this run.
            assert!(
                grads_payload_bytes(&shapes) > MAX_PAYLOAD,
                "x64 must exceed the dense payload cap to prove anything"
            );
        }
        for shards in [1usize, 2] {
            for clients in [1usize, 4] {
                let tag = format!("x{scale}_{shards}s_{clients}c");
                let snap = tmp(&tag);
                let refp = tmp(&format!("{tag}_ref"));
                let mut opts = serve_opts(shards, clients);
                opts.model = spec.clone();
                let server = Server::start(&cfg, &opts).unwrap();
                let addr = server.addr.to_string();
                let report = run_loadgen(
                    &addr,
                    &shapes,
                    cfg.seed,
                    &LoadgenOptions { clients, steps, ..LoadgenOptions::default() },
                )
                .unwrap();
                let mut ctl = Client::connect(&addr).unwrap();
                let bytes = ctl.snapshot(snap.to_str().unwrap()).unwrap();
                ctl.shutdown().unwrap();
                server.wait().unwrap();

                assert_eq!(report.pushes, clients as u64 * steps, "{tag}");
                assert!(report.bytes_per_step > 0.0, "{tag}: {report:?}");

                let ref_loss = reference_checkpoint(&cfg, &spec, clients, steps, &refp).unwrap();
                let got = std::fs::read(&snap).unwrap();
                let want = std::fs::read(&refp).unwrap();
                assert_eq!(got.len() as u64, bytes, "{tag}: SnapshotDone size");
                assert!(got == want, "{tag}: streamed snapshot differs from the dense reference");
                assert_eq!(report.final_loss.to_bits(), ref_loss.to_bits(), "{tag}");

                std::fs::remove_file(&snap).ok();
                std::fs::remove_file(&refp).ok();
            }
        }
    }
}

/// The factored pull mode: an SMMF server ships its optimizer state as
/// factor vectors + packed sign planes, and the client reconstructs
/// dense momenta — shapes right, second moments non-negative (they are
/// outer products of non-negative factors), and the whole exchange far
/// smaller on the wire than the dense momenta it reconstructs.
#[test]
fn factored_pull_reconstructs_dense_momenta_from_compressed_state() {
    let steps = 4u64;
    let cfg = test_config(OptKind::Smmf);
    let shapes = inventory_by_name("tiny_lm").unwrap().shapes();
    let server = Server::start(&cfg, &serve_opts(1, 1)).unwrap();
    let addr = server.addr.to_string();
    run_loadgen(
        &addr,
        &shapes,
        cfg.seed,
        &LoadgenOptions { clients: 1, steps, ..LoadgenOptions::default() },
    )
    .unwrap();
    let mut ctl = Client::connect(&addr).unwrap();
    let before = ctl.bytes_received;
    let (at, moments) = ctl.pull_state_factored().unwrap();
    let factored_bytes = ctl.bytes_received - before;
    ctl.shutdown().unwrap();
    server.wait().unwrap();

    assert_eq!(at, steps);
    assert_eq!(moments.len(), shapes.len());
    let mut total_numel = 0usize;
    let mut saw_signal = false;
    for (t, (m, s)) in moments.iter().zip(&shapes).enumerate() {
        let numel: usize = s.iter().product();
        total_numel += numel;
        match m {
            TensorMoments::Dense { m, v } => {
                assert_eq!((m.len(), v.len()), (numel, numel), "tensor {t}");
                assert!(v.iter().all(|x| *x >= 0.0), "tensor {t}: V̂ went negative");
                saw_signal |= m.iter().any(|x| *x != 0.0);
            }
            TensorMoments::Stateless => panic!("tensor {t}: tiny_lm has no frozen tensors"),
        }
    }
    assert!(saw_signal, "four steps of training left all first moments at zero");
    // The compression story on the wire: dense momenta would be
    // 8 bytes/element; the factored stream must come in well under.
    assert!(
        factored_bytes < (8 * total_numel) as u64 / 2,
        "factored pull moved {factored_bytes} bytes for {total_numel} elements"
    );
}

/// Regression pin for the loadgen width probe (the race fixed in this
/// revision): a member that `Join`s concurrently with an async
/// loadgen's startup must not make the probe bail on the transient
/// width — the probe polls until the membership covers the driver
/// count. Before the fix this failed with a spurious member-table
/// mismatch whenever the Join landed after the one-shot Stats read.
#[test]
fn async_loadgen_probe_waits_for_a_joining_member() {
    let steps = 3u64;
    let cfg = test_config(OptKind::Smmf);
    let shapes = inventory_by_name("tiny_lm").unwrap().shapes();
    let opts = ServeOptions { staleness: 2, ..serve_opts(1, 1) };
    let server = Server::start(&cfg, &opts).unwrap();
    let addr = server.addr.to_string();

    let report = std::thread::scope(|s| {
        s.spawn(|| {
            // Land the Join a beat after the loadgen's first probe.
            std::thread::sleep(std::time::Duration::from_millis(120));
            let mut c = Client::connect(&addr).unwrap();
            let view = c.join().unwrap();
            assert_eq!(view.client, 1);
        });
        run_loadgen(
            &addr,
            &shapes,
            cfg.seed,
            &LoadgenOptions { clients: 2, steps, ..LoadgenOptions::default() },
        )
        .unwrap()
    });
    assert_eq!(report.staleness, 2, "{report:?}");
    assert_eq!(report.pushes, 2 * steps, "{report:?}");

    Client::connect(&addr).unwrap().shutdown().unwrap();
    server.wait().unwrap();
}

/// The observability contract's load-bearing half: running the exact
/// same server + loadgen cell with the flight recorder and metrics ON
/// produces a snapshot byte-identical to the untraced run (which is
/// itself pinned to the reference above). Tracing only reads a clock
/// and writes to its own rings — it must never perturb the math.
///
/// While traced, the test also exercises the new `MetricsDump` wire op
/// and checks the recorder actually captured optimizer-phase and
/// server-commit spans — so this can't silently pass with
/// instrumentation compiled out.
#[test]
fn traced_run_is_bit_identical_to_untraced_run() {
    let steps = 8u64;
    let cfg = test_config(OptKind::Smmf);
    let shapes = inventory_by_name("tiny_lm").unwrap().shapes();
    let mut files = Vec::new();
    for traced in [false, true] {
        smmf_repro::obs::set_trace_enabled(traced);
        smmf_repro::obs::set_metrics_enabled(traced);
        let snap = tmp(&format!("traced_{traced}"));
        let server = Server::start(&cfg, &serve_opts(2, 2)).unwrap();
        let addr = server.addr.to_string();
        run_loadgen(
            &addr,
            &shapes,
            cfg.seed,
            &LoadgenOptions { clients: 2, steps, ..LoadgenOptions::default() },
        )
        .unwrap();
        let mut ctl = Client::connect(&addr).unwrap();
        ctl.snapshot(snap.to_str().unwrap()).unwrap();
        if traced {
            // The MetricsDump op answers with live exposition text fed
            // by the same counters that back StatsReply.
            let text = ctl.metrics_dump().unwrap();
            assert!(
                text.contains("smmf_server_pushes_total 16\n"),
                "exposition disagrees with the run: {text}"
            );
            assert!(text.contains("# TYPE smmf_server_commit_ms summary\n"), "{text}");
            assert!(text.contains("smmf_server_stream_rx_bytes_total"), "{text}");
        }
        ctl.shutdown().unwrap();
        server.wait().unwrap();
        files.push(std::fs::read(&snap).unwrap());
        std::fs::remove_file(&snap).ok();
    }
    smmf_repro::obs::set_trace_enabled(false);
    smmf_repro::obs::set_metrics_enabled(false);

    assert!(files[0] == files[1], "tracing changed the snapshot bits");

    // The traced pass must have recorded real spans from both layers.
    let dump = smmf_repro::obs::trace::global().drain();
    let has = |n: &str| dump.events.iter().any(|e| e.name == n);
    assert!(has("optim.step"), "no optimizer step spans recorded");
    assert!(has("optim.factor_update"), "no SMMF factor-update spans recorded");
    assert!(has("server.push"), "no server push spans recorded");
    assert!(has("server.commit"), "no server commit spans recorded");
}
