//! End-to-end tests for the optimizer-state server: the determinism
//! contract (a K-shard server driven by N concurrent TCP clients writes
//! a snapshot byte-identical to the equivalent single-process trainer,
//! at shards {1,2} × clients {1,4}), the loadgen measurements, and the
//! wire-level error paths.
//!
//! Everything here runs over real loopback TCP against the `tiny_lm`
//! inventory (~15K params) — no AOT artifacts, no PJRT.

use std::path::PathBuf;

use smmf_repro::coordinator::ExperimentConfig;
use smmf_repro::models::inventory_by_name;
use smmf_repro::optim::OptKind;
use smmf_repro::server::{
    reference_checkpoint, run_loadgen, Client, LoadgenOptions, Msg, ServeOptions, Server,
};
use smmf_repro::train::checkpoint;

fn tmp(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("smmf_server_{tag}_{}.bin", std::process::id()))
}

fn test_config(kind: OptKind) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.optimizer = kind;
    cfg.optim = smmf_repro::optim::OptimConfig::paper_defaults(kind);
    cfg.optim.lr = 0.05;
    cfg.seed = 3;
    cfg
}

fn serve_opts(shards: usize, clients: usize) -> ServeOptions {
    ServeOptions {
        addr: "127.0.0.1:0".into(),
        model: "synthetic:tiny_lm".into(),
        shards,
        clients,
        max_pending: 64,
    }
}

/// The acceptance matrix: shards {1,2} × clients {1,4}, snapshot
/// bit-identity against the single-process reference trainer.
#[test]
fn sharded_concurrent_snapshot_is_bit_identical_to_reference() {
    let steps = 12u64;
    let shapes = inventory_by_name("tiny_lm").unwrap().shapes();
    for kind in [OptKind::Smmf, OptKind::Adam] {
        let cfg = test_config(kind);
        for shards in [1usize, 2] {
            for clients in [1usize, 4] {
                let tag = format!("{}_{shards}s_{clients}c", kind.name());
                let snap = tmp(&tag);
                let refp = tmp(&format!("{tag}_ref"));

                let server = Server::start(&cfg, &serve_opts(shards, clients)).unwrap();
                let addr = server.addr.to_string();
                let report =
                    run_loadgen(&addr, &shapes, cfg.seed, &LoadgenOptions { clients, steps })
                        .unwrap();
                let mut ctl = Client::connect(&addr).unwrap();
                let bytes = ctl.snapshot(snap.to_str().unwrap()).unwrap();
                let stats = ctl.stats().unwrap();
                ctl.shutdown().unwrap();
                let final_stats = server.wait().unwrap();

                assert_eq!(stats.step, steps, "{tag}");
                assert_eq!(stats.pushes, clients as u64 * steps, "{tag}");
                assert_eq!(final_stats.snapshots, 1, "{tag}");
                assert_eq!(report.pushes, clients as u64 * steps, "{tag}");

                let ref_loss =
                    reference_checkpoint(&cfg, "synthetic:tiny_lm", clients, steps, &refp)
                        .unwrap();
                let got = std::fs::read(&snap).unwrap();
                let want = std::fs::read(&refp).unwrap();
                assert_eq!(got.len() as u64, bytes, "{tag}: SnapshotDone size");
                assert!(got == want, "{tag}: snapshot differs from the reference");
                // the client-observed objective matches the reference's
                assert_eq!(report.final_loss.to_bits(), ref_loss.to_bits(), "{tag}");
                // the well actually converges (sanity that training ran)
                assert!(report.final_loss < 0.125, "{tag}: loss {}", report.final_loss);

                // A snapshot is a regular SMMFCKPT v2 file with the full
                // section set.
                let ck = checkpoint::load_any(&snap).unwrap();
                assert_eq!(ck.step, steps, "{tag}");
                assert_eq!(ck.opt.as_ref().unwrap().kind, kind, "{tag}");
                assert!(ck.schedule.is_some() && ck.config.is_some(), "{tag}");

                std::fs::remove_file(&snap).ok();
                std::fs::remove_file(&refp).ok();
            }
        }
    }
}

/// Sharding is invisible in the bits: the same run on 1 vs 2 shards
/// produces identical snapshots (both already equal the reference; this
/// pins the transitive property directly as well).
#[test]
fn shard_count_does_not_change_the_snapshot() {
    let steps = 8u64;
    let cfg = test_config(OptKind::Smmf);
    let shapes = inventory_by_name("tiny_lm").unwrap().shapes();
    let mut files = Vec::new();
    for shards in [1usize, 2] {
        let snap = tmp(&format!("shardcmp_{shards}"));
        let server = Server::start(&cfg, &serve_opts(shards, 2)).unwrap();
        let addr = server.addr.to_string();
        run_loadgen(&addr, &shapes, cfg.seed, &LoadgenOptions { clients: 2, steps }).unwrap();
        let mut ctl = Client::connect(&addr).unwrap();
        ctl.snapshot(snap.to_str().unwrap()).unwrap();
        ctl.shutdown().unwrap();
        server.wait().unwrap();
        files.push(std::fs::read(&snap).unwrap());
        std::fs::remove_file(&snap).ok();
    }
    assert!(files[0] == files[1], "1-shard vs 2-shard snapshots differ");
}

#[test]
fn loadgen_reports_finite_latencies_and_throughput() {
    let cfg = test_config(OptKind::Smmf);
    let shapes = inventory_by_name("tiny_lm").unwrap().shapes();
    let server = Server::start(&cfg, &serve_opts(2, 3)).unwrap();
    let addr = server.addr.to_string();
    let report =
        run_loadgen(&addr, &shapes, cfg.seed, &LoadgenOptions { clients: 3, steps: 6 }).unwrap();
    Client::connect(&addr).unwrap().shutdown().unwrap();
    server.wait().unwrap();
    assert_eq!(report.clients, 3);
    assert_eq!(report.steps, 6);
    assert!(report.steps_per_s > 0.0, "{report:?}");
    assert!(report.push_p50_ms.is_finite() && report.push_p50_ms >= 0.0, "{report:?}");
    assert!(report.push_p99_ms >= report.push_p50_ms, "{report:?}");
    assert!(report.push_mean_ms.is_finite(), "{report:?}");
    assert!(report.elapsed_s > 0.0);
}

/// Wire-level error paths: bad pushes are rejected with Err (not a
/// hang, not a dropped connection), replies are not accepted as
/// requests, and the connection survives to serve further requests.
#[test]
fn server_rejects_bad_requests_and_keeps_serving() {
    let cfg = test_config(OptKind::Smmf);
    let server = Server::start(&cfg, &serve_opts(1, 2)).unwrap();
    let addr = server.addr.to_string();
    let mut c = Client::connect(&addr).unwrap();

    // unknown client id
    let reply = c.call(Msg::PushGrad { client: 9, step: 1, grads: vec![] }).unwrap();
    assert!(matches!(reply, Msg::Err { .. }), "{}", reply.name());
    // wrong step
    let reply = c.call(Msg::PushGrad { client: 0, step: 5, grads: vec![] }).unwrap();
    assert!(matches!(reply, Msg::Err { .. }), "{}", reply.name());
    // wrong tensor count (right client, right step)
    let reply = c.call(Msg::PushGrad { client: 0, step: 1, grads: vec![vec![1.0]] }).unwrap();
    assert!(matches!(reply, Msg::Err { .. }), "{}", reply.name());
    // a reply op sent as a request is rejected by the handler
    let reply = c.call(Msg::Ack { step: 1 }).unwrap();
    assert!(matches!(reply, Msg::Err { .. }), "{}", reply.name());
    // snapshot to an unwritable path errors instead of killing the server
    let reply = c.call(Msg::Snapshot { path: "/definitely/not/a/dir/x.bin".into() }).unwrap();
    assert!(matches!(reply, Msg::Err { .. }), "{}", reply.name());

    // a loadgen whose client count disagrees with the server's barrier
    // width fails loudly up front instead of deadlocking the barrier
    let shapes = inventory_by_name("tiny_lm").unwrap().shapes();
    let e = run_loadgen(&addr, &shapes, cfg.seed, &LoadgenOptions { clients: 1, steps: 1 })
        .unwrap_err();
    assert!(format!("{e:#}").contains("barrier"), "{e:#}");

    // …and the same connection still works
    let (step, tensors) = c.pull_params().unwrap();
    assert_eq!(step, 0);
    assert_eq!(tensors.len(), inventory_by_name("tiny_lm").unwrap().tensors.len());
    let stats = c.stats().unwrap();
    assert_eq!(stats.step, 0);
    assert_eq!((stats.shards, stats.clients), (1, 2));
    c.shutdown().unwrap();
    server.wait().unwrap();
}
