//! Suite subsystem tests: TOML expansion (cartesian counts, override
//! precedence, bad-key rejection), the artifact-free synthetic runner
//! (end-to-end with resume-aware re-entry and failure isolation), and
//! report-generator determinism over the checked-in fixture summaries.

use std::path::{Path, PathBuf};

use smmf_repro::coordinator::config::{SuiteCell, SuiteConfig};
use smmf_repro::coordinator::report;
use smmf_repro::coordinator::suite::{run_suite, CellStatus, SuiteOptions};
use smmf_repro::optim::OptKind;

fn fixture_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/suite_report/smoke")
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("smmf_suite_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

const SMOKE: &str = r#"
[suite]
name = "smoke"
seeds = [0, 1]

[optimizer]
lr = 0.05

[train]
steps = 8
log_every = 4

[[suite.run]]
optimizers = ["adam", "smmf"]
models = ["synthetic:tiny_lm"]
"#;

#[test]
fn cartesian_expansion_counts_and_names() {
    let cfg = SuiteConfig::parse(SMOKE, "fallback").unwrap();
    assert_eq!(cfg.name, "smoke");
    assert_eq!(cfg.seeds, vec![0, 1]);
    let cells = cfg.expand().unwrap();
    // 2 optimizers × 1 model × 2 seeds
    assert_eq!(cells.len(), 4);
    let names: Vec<&str> = cells.iter().map(|c| c.run.as_str()).collect();
    assert_eq!(
        names,
        vec!["tiny_lm-adam-s0", "tiny_lm-adam-s1", "tiny_lm-smmf-s0", "tiny_lm-smmf-s1"]
    );
    for c in &cells {
        assert_eq!(c.cfg.name, format!("smoke/{}", c.run));
        assert_eq!(c.cfg.out_dir, "runs");
        assert_eq!(c.cfg.steps, 8);
        assert!((c.cfg.optim.lr - 0.05).abs() < 1e-7, "lr survives retarget");
        assert_eq!(c.model, "synthetic:tiny_lm");
    }
    // per-optimizer paper defaults are re-derived per cell
    let adam: &SuiteCell = &cells[0];
    assert_eq!(adam.optimizer, OptKind::Adam);
    assert!(!adam.cfg.optim.bias_correction, "paper pre-training default");
    // multi-block, multi-model, block seed list
    let big = r#"
[suite]
name = "big"
seeds = [0]

[[suite.run]]
optimizers = ["adam", "smmf", "sm3"]
models = ["synthetic:tiny_lm", "lm_tiny_grads"]
seeds = [3, 4]

[[suite.run]]
label = "lowlr"
optimizers = ["smmf"]
models = ["synthetic:tiny_lm"]
"#;
    let cfg = SuiteConfig::parse(big, "x").unwrap();
    let cells = cfg.expand().unwrap();
    // 3 × 2 × 2 + 1 × 1 × 1 (second block inherits [suite] seeds)
    assert_eq!(cells.len(), 13);
    assert!(cells.iter().any(|c| c.run == "lowlr-tiny_lm-smmf-s0"));
    assert!(cells.iter().any(|c| c.run == "lm_tiny_grads-sm3-s4"));
    // the same (opt, model, seed) in both blocks is only legal via label
    assert!(cells.iter().filter(|c| c.run.contains("tiny_lm-smmf")).count() >= 3);
}

#[test]
fn override_precedence_block_beats_train_beats_default() {
    let text = r#"
[suite]
name = "prec"

[optimizer]
lr = 0.004

[train]
steps = 50

[[suite.run]]
optimizers = ["adam"]
models = ["synthetic:tiny_lm"]

[[suite.run]]
label = "short"
optimizers = ["adam"]
models = ["synthetic:tiny_lm"]
steps = 10
lr = 0.01
weight_decay = 0.1
threads = 4
log_every = 5
"#;
    let cfg = SuiteConfig::parse(text, "x").unwrap();
    let cells = cfg.expand().unwrap();
    assert_eq!(cells.len(), 2);
    let base = cells.iter().find(|c| c.run == "tiny_lm-adam-s0").unwrap();
    assert_eq!(base.cfg.steps, 50, "[train] steps applies when block has none");
    assert!((base.cfg.optim.lr - 0.004).abs() < 1e-9);
    let short = cells.iter().find(|c| c.run == "short-tiny_lm-adam-s0").unwrap();
    assert_eq!(short.cfg.steps, 10, "block steps beats [train]");
    assert!((short.cfg.optim.lr - 0.01).abs() < 1e-9, "block lr beats [optimizer]");
    assert!((short.cfg.optim.weight_decay - 0.1).abs() < 1e-9);
    assert_eq!(short.cfg.optim.threads, 4);
    assert_eq!(short.cfg.log_every, 5);
    // default seed list is [0]
    assert_eq!(cfg.seeds, vec![0]);
}

#[test]
fn bad_suite_files_are_rejected() {
    let run = "\n[[suite.run]]\noptimizers = [\"adam\"]\nmodels = [\"m\"]\n";
    // unknown [[suite.run]] key (typo'd dimension must not be dropped)
    let e = SuiteConfig::parse(
        "[[suite.run]]\noptimizerz = [\"adam\"]\nmodels = [\"m\"]\n",
        "x",
    )
    .unwrap_err();
    assert!(e.to_string().contains("unknown key optimizerz"), "{e}");
    // unknown [suite] key
    let e = SuiteConfig::parse(&format!("[suite]\nseedz = [1]\n{run}"), "x").unwrap_err();
    assert!(e.to_string().contains("unknown key seedz"), "{e}");
    // unknown optimizer name
    let e = SuiteConfig::parse("[[suite.run]]\noptimizers = [\"adamx\"]\nmodels = [\"m\"]\n", "x")
        .unwrap_err();
    assert!(e.to_string().contains("unknown optimizer adamx"), "{e}");
    // missing required keys / empty file
    assert!(SuiteConfig::parse("", "x").unwrap_err().to_string().contains("no [[suite.run]]"));
    assert!(SuiteConfig::parse("[[suite.run]]\nmodels = [\"m\"]\n", "x")
        .unwrap_err()
        .to_string()
        .contains("missing optimizers"));
    assert!(SuiteConfig::parse("[[suite.run]]\noptimizers = [\"adam\"]\n", "x")
        .unwrap_err()
        .to_string()
        .contains("missing models"));
    // type errors and bad values
    for bad in [
        "[[suite.run]]\noptimizers = [\"adam\"]\nmodels = [\"m\"]\nsteps = \"ten\"\n",
        "[[suite.run]]\noptimizers = [\"adam\"]\nmodels = [\"m\"]\nsteps = 0\n",
        "[[suite.run]]\noptimizers = [\"adam\"]\nmodels = [\"m\"]\nseeds = [-1]\n",
        "[[suite.run]]\noptimizers = [\"adam\"]\nmodels = [\"m\"]\nlabel = \"a/b\"\n",
        "[suite]\nname = \"a/b\"\n[[suite.run]]\noptimizers = [\"adam\"]\nmodels = [\"m\"]\n",
        "[suite]\nseeds = []\n[[suite.run]]\noptimizers = [\"adam\"]\nmodels = [\"m\"]\n",
    ] {
        assert!(SuiteConfig::parse(bad, "x").is_err(), "accepted: {bad}");
    }
    // duplicate cells across blocks error at expansion (label fixes it)
    let dup = "[[suite.run]]\noptimizers = [\"adam\"]\nmodels = [\"m\"]\n\
               [[suite.run]]\noptimizers = [\"adam\"]\nmodels = [\"m\"]\n";
    let e = SuiteConfig::parse(dup, "x").unwrap().expand().unwrap_err();
    assert!(e.to_string().contains("re-expands"), "{e}");
}

#[test]
fn synthetic_suite_end_to_end_reentry_and_failure_isolation() {
    let tmp = tmp_dir("e2e");
    let mut cfg = SuiteConfig::parse(SMOKE, "x").unwrap();
    cfg.out_dir = tmp.to_str().unwrap().to_string();
    let opts = SuiteOptions::default();

    // First pass trains everything.
    let out1 = run_suite(&cfg, &opts).unwrap();
    assert_eq!(out1.counts(), (4, 0, 0), "4 cells ran");
    let suite_dir = out1.suite_dir.clone();
    for c in &out1.cells {
        assert!(suite_dir.join(&c.0.run).join("summary.json").exists(), "{}", c.0.run);
    }
    let docs1 = tmp.join("RESULTS.1.md");
    report::write_report("smoke", &suite_dir, &docs1, &tmp.join("B1.json")).unwrap();

    // Second pass: resume-aware re-entry skips every cached cell and the
    // regenerated report is byte-identical (the acceptance criterion).
    let out2 = run_suite(&cfg, &opts).unwrap();
    assert_eq!(out2.counts(), (0, 4, 0), "all cells cached");
    let docs2 = tmp.join("RESULTS.2.md");
    report::write_report("smoke", &suite_dir, &docs2, &tmp.join("B2.json")).unwrap();
    let (b1, b2) = (std::fs::read(&docs1).unwrap(), std::fs::read(&docs2).unwrap());
    assert_eq!(b1, b2, "byte-identical report across re-entry");
    let md = String::from_utf8(b1).unwrap();
    for section in
        ["## Optimizer-state memory", "## Quality — final loss", "## Throughput", "vs adam"]
    {
        assert!(md.contains(section), "missing {section:?} in:\n{md}");
    }
    // SMMF's measured state is a small fraction of Adam's on tiny_lm.
    let adam_row = md.lines().find(|l| l.contains("| adam |")).unwrap();
    let smmf_row = md.lines().find(|l| l.contains("| smmf |")).unwrap();
    assert!(adam_row.contains("1.000x"), "{adam_row}");
    let ratio: f64 = smmf_row
        .rsplit('|')
        .nth(1)
        .unwrap()
        .trim()
        .trim_end_matches('x')
        .parse()
        .unwrap();
    assert!(ratio < 0.25, "smmf vs adam ratio {ratio} not small; row: {smmf_row}");

    // Determinism across *fresh* trainings of the same seeds: quality and
    // memory cells are bit-reproducible (timing is not, so compare the
    // summaries' deterministic fields).
    let tmp_b = tmp_dir("e2e_b");
    let mut cfg_b = cfg.clone();
    cfg_b.out_dir = tmp_b.to_str().unwrap().to_string();
    run_suite(&cfg_b, &opts).unwrap();
    for run in ["tiny_lm-adam-s0", "tiny_lm-smmf-s1"] {
        let a = std::fs::read_to_string(suite_dir.join(run).join("summary.json")).unwrap();
        let b = std::fs::read_to_string(tmp_b.join("smoke").join(run).join("summary.json"))
            .unwrap();
        let field = |text: &str, key: &str| {
            let j = smmf_repro::util::json::Json::parse(text).unwrap();
            j.get(key).and_then(smmf_repro::util::json::Json::as_f64).unwrap()
        };
        for key in ["first_loss", "final_loss", "opt_state_bytes", "param_count"] {
            assert_eq!(field(&a, key), field(&b, key), "{run}: {key}");
        }
    }

    // Failure isolation: an unknown synthetic inventory fails its cells
    // but the rest of the suite still runs, and the report lists them.
    let tmp_f = tmp_dir("fail");
    let mut cfg_f = SuiteConfig::parse(
        r#"
[suite]
name = "mixed"
[train]
steps = 4
[[suite.run]]
optimizers = ["adam"]
models = ["synthetic:tiny_lm", "synthetic:no_such_model"]
"#,
        "x",
    )
    .unwrap();
    cfg_f.out_dir = tmp_f.to_str().unwrap().to_string();
    let out = run_suite(&cfg_f, &SuiteOptions::default()).unwrap();
    assert_eq!(out.counts(), (1, 0, 1));
    let failed = out
        .cells
        .iter()
        .find(|(_, s)| matches!(s, CellStatus::Failed(_)))
        .unwrap();
    assert!(out.suite_dir.join(&failed.0.run).join("FAILED").exists());
    let cells = report::collect(&out.suite_dir).unwrap();
    assert_eq!(cells.len(), 2);
    let (mdout, _) = report::generate("mixed", &cells);
    assert!(mdout.contains("## Failed cells"), "{mdout}");
    assert!(mdout.contains("no_such_model-adam-s0"), "{mdout}");
    assert!(mdout.contains("Cells: 1 ok, 1 failed."), "{mdout}");

    for d in [tmp, tmp_b, tmp_f] {
        let _ = std::fs::remove_dir_all(d);
    }
}

#[test]
fn golden_report_is_deterministic_over_fixtures() {
    let cells = report::collect(&fixture_dir()).unwrap();
    assert_eq!(cells.len(), 5, "4 ok + 1 FAILED fixture cells");
    let (md1, rec1) = report::generate("smoke", &cells);
    // Re-collect + re-generate: byte-identical output from fixed inputs.
    let cells2 = report::collect(&fixture_dir()).unwrap();
    let (md2, rec2) = report::generate("smoke", &cells2);
    assert_eq!(md1, md2);
    assert_eq!(rec1.len(), rec2.len());
    // Spot-check the aggregation the tables are built from.
    assert!(md1.contains("Cells: 4 ok, 1 failed."), "{md1}");
    assert!(md1.contains("| synthetic:tiny_lm | adam | 14.8K | 115.0 KiB | 117760 | 1.000x |"));
    assert!(md1.contains("| synthetic:tiny_lm | smmf | 14.8K | 2.9 KiB | 2944 | 0.025x |"));
    assert!(md1.contains("| synthetic:tiny_lm | adam | 2 | 0.1250 | 0.0125 ± 0.0002 |"));
    assert!(md1.contains("| synthetic:tiny_lm | smmf | 2 | 0.1250 | 0.0135 ± 0.0004 |"));
    assert!(md1.contains("| synthetic:tiny_lm | adam | 0.25 | 4000 |"));
    assert!(md1.contains("| synthetic:tiny_lm | smmf | 0.40 | 2500 |"));
    assert!(md1.contains("| tiny_lm-sgd-s0 | synthetic workload diverged"), "{md1}");
    // `make docs-check` pins docs/RESULTS.md to exactly this output; keep
    // them in sync by regenerating via `repro report` when this changes.
}

#[test]
fn corrupt_summary_surfaces_as_failed_cell() {
    // A truncated summary.json (e.g. written before the atomic-rename
    // fix, or a torn disk) must show up in the failed table, not vanish.
    let tmp = tmp_dir("corrupt");
    let cell = tmp.join("tiny_lm-adam-s0");
    std::fs::create_dir_all(&cell).unwrap();
    std::fs::write(cell.join("summary.json"), "{\"final_loss\":0.0").unwrap();
    let cells = report::collect(&tmp).unwrap();
    assert_eq!(cells.len(), 1);
    assert!(
        cells[0].failed.as_deref().unwrap_or("").contains("unreadable summary.json"),
        "{:?}",
        cells[0].failed
    );
    let (md, _) = report::generate("corrupt", &cells);
    assert!(md.contains("Cells: 0 ok, 1 failed."), "{md}");
    assert!(md.contains("unreadable summary.json"), "{md}");
    let _ = std::fs::remove_dir_all(tmp);
}

#[test]
fn report_falls_back_to_analytic_adam_reference() {
    // A suite that never ran adam still gets a ratio column, computed
    // from optim::memory over the model's inventory.
    let cells = vec![report::CellRecord {
        run: "tiny_lm-smmf-s0".into(),
        model: "synthetic:tiny_lm".into(),
        optimizer: "smmf".into(),
        seed: 0,
        steps: 4,
        first_loss: Some(0.5),
        final_loss: Some(0.25),
        mean_step_ms: 1.0,
        opt_state_bytes: 2944,
        param_count: Some(14752),
        failed: None,
    }];
    let (md, _) = report::generate("solo", &cells);
    let row = md.lines().find(|l| l.contains("| smmf |")).unwrap().to_string();
    let ratio = row.rsplit('|').nth(1).unwrap().trim().to_string();
    assert!(ratio.ends_with('x') && ratio != "—", "expected analytic ratio, got {ratio}: {row}");
    let r: f64 = ratio.trim_end_matches('x').parse().unwrap();
    // Adam on 14752 params = 118016 bytes -> 2944/118016 ≈ 0.0249
    assert!((r - 0.025).abs() < 0.002, "{r}");
    // An artifact model with no adam cell has no reference -> em dash.
    let cells = vec![report::CellRecord {
        run: "lm-smmf-s0".into(),
        model: "lm_tiny_grads".into(),
        optimizer: "smmf".into(),
        seed: 0,
        steps: 4,
        first_loss: Some(0.5),
        final_loss: Some(0.25),
        mean_step_ms: 1.0,
        opt_state_bytes: 1000,
        param_count: None,
        failed: None,
    }];
    let (md, _) = report::generate("solo2", &cells);
    let row = md.lines().find(|l| l.contains("| smmf |")).unwrap();
    assert!(row.ends_with("| — |"), "{row}");
}
