#!/usr/bin/env bash
# Pre-merge smoke: build, test, checkpoint-roundtrip, and quick-bench the
# optimizer suite so regressions in the fused/parallel step paths and the
# checkpoint/resume subsystem are caught before merge.
#
#   bash rust/tests/smoke.sh            # from the repo root
#   make smoke                          # equivalent
#
# The quick bench also refreshes BENCH_optimizer_step.json (the perf
# trajectory tracked across PRs, now including the SMMF-vs-Adam
# checkpoint size ratio) unless SMMF_BENCH_JSON overrides the output
# path.
set -euo pipefail

cd "$(dirname "$0")/.."   # rust/

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== checkpoint-roundtrip (bit-identical resume, all optimizers) =="
cargo test --release --test checkpoint_roundtrip

echo "== grouped API (default-group bit-identity, wd exemption, grouped resume) =="
cargo test --release --test grouped_build

echo "== suite subsystem (expansion, synthetic cells, report determinism) =="
cargo test --release --test suite

echo "== server wire codec (roundtrip + corruption) =="
cargo test --release --test server_protocol

echo "== server e2e (K-shard x N-client snapshot bit-identity) =="
cargo test --release --test server_e2e

echo "== server replay (async commit-log replay + staleness window) =="
cargo test --release --test server_replay

echo "== suite wire codec (SMMFCELL roundtrip + corruption) =="
cargo test --release --test remote_protocol

echo "== remote dispatch (worker specs, wire TOML, dead-worker isolation) =="
cargo test --release --test remote_dispatch

echo "== remote e2e (2-worker fan-out, mid-suite crash, byte-identical reports) =="
cargo test --release --test remote_e2e

echo "== CLI help drift guard =="
cargo test --release --test cli_help

echo "== observability (flight recorder, registry, pinned export bytes) =="
cargo test --release --test obs

# Suite smoke: 2 optimizers × 1 model × 2 seeds on the artifact-free
# synthetic workload, run twice — the second pass must skip every cached
# cell and re-render a byte-identical report (the docs/RESULTS.md
# determinism contract).
echo "== suite smoke (repro suite tests/suite_smoke.toml, twice) =="
rm -rf target/suite-smoke
cargo run --release -- suite tests/suite_smoke.toml \
  --out-dir target/suite-smoke --docs target/suite-smoke/RESULTS.md \
  --bench-json target/suite-smoke/BENCH_suite.json
cargo run --release -- suite tests/suite_smoke.toml \
  --out-dir target/suite-smoke --docs target/suite-smoke/RESULTS.2.md \
  --bench-json target/suite-smoke/BENCH_suite.2.json
cmp target/suite-smoke/RESULTS.md target/suite-smoke/RESULTS.2.md

# Remote-suite smoke: the same suite dispatched to two real `repro
# worker` processes over SMMFCELL, twice (second pass all-cached), then
# a local-pool pass — reports must be byte-identical across backends.
echo "== remote smoke (2 loopback workers, byte-identical reports) =="
bash tests/remote_smoke.sh

# Server smoke: loopback optimizer-state server, 4 clients × 2 shards
# on the synthetic workload; --check asserts the snapshot is
# byte-identical to the single-process reference trainer and the run
# refreshes the BENCH_server.json throughput/latency record.
echo "== server smoke (repro loadgen --check, 2 shards x 4 clients) =="
cargo run --release -- loadgen --model synthetic:tiny_lm \
  --clients 4 --shards 2 --steps 30 \
  --snapshot target/serve-smoke/snapshot.bin --check \
  --bench-json "${SMMF_SERVER_BENCH_JSON:-../BENCH_server.json}"

# Chaos smoke: the fault-tolerance contract from the CLI. First drop
# one client mid-run *and* kill one shard worker mid-run — --check pins
# the final snapshot against the elastic reference trainer for the
# surviving epoch schedule (eviction lands deterministically at
# drop + 1; the killed shard respawns from the recovery image). Then a
# slow (but live) client under an armed eviction deadline: the run must
# finish, not evict, and record degraded-vs-healthy throughput.
echo "== chaos smoke (drop-client + kill-shard, --check vs elastic reference) =="
cargo run --release -- loadgen --model synthetic:tiny_lm \
  --clients 3 --shards 2 --steps 20 \
  --drop-client 8 --kill-shard 5 --client-timeout-ms 400 \
  --snapshot target/chaos-smoke/snapshot.bin --check \
  --bench-json target/chaos-smoke/BENCH_chaos.json

echo "== chaos smoke (slow client under an armed eviction deadline) =="
cargo run --release -- loadgen --model synthetic:tiny_lm \
  --clients 3 --shards 2 --steps 12 \
  --slow-client 40 --client-timeout-ms 2000 \
  --bench-json "${SMMF_SERVER_BENCH_JSON:-../BENCH_server.json}"

# Async smoke: bounded-staleness ingestion (window 4) with a straggler
# client. The run records every applied partial batch to the commit
# log; `repro replay` then re-executes the log through the synchronous
# sharded machinery and the replayed snapshot must match the async
# server's byte for byte — the async analogue of --check.
echo "== async smoke (staleness 4 + straggler, commit-log replay byte-compare) =="
cargo run --release -- loadgen --model synthetic:tiny_lm \
  --clients 4 --shards 2 --steps 30 \
  --staleness 4 --slow-client 20 \
  --commit-log target/async-smoke/commits.bin \
  --snapshot target/async-smoke/snapshot.bin \
  --bench-json target/async-smoke/BENCH_async.json
cargo run --release -- replay target/async-smoke/commits.bin \
  --shards 2 --snapshot target/async-smoke/replay.bin
cmp target/async-smoke/snapshot.bin target/async-smoke/replay.bin

# Stream smoke: the chunked v4 wire path at paper scale. Runs the
# cross-protocol corruption battery and the chunk-stream property
# tests, then drives loadgen --check at 1x/8x/64x inventory scale —
# the 64x inventory only serves chunked (its dense gradient set
# exceeds the live-frame cap) and its streamed snapshot must be
# byte-identical to the dense reference. This is the run that leaves
# the final BENCH_server.json refresh (per-scale steps/s + bytes/step).
echo "== stream smoke (corruption battery + 1x/8x/64x loadgen --check) =="
bash tests/stream_smoke.sh

# Observability smoke: the same loadgen --check cell, but run through
# `repro trace` — the flight recorder and metrics registry are forced
# on, and the snapshot must STILL be byte-identical to the reference
# (the non-perturbation contract). The run leaves a Chrome trace JSON
# (optimizer-phase + server-commit spans), the Prometheus exposition,
# and measured obs/ histogram records merged into BENCH_server.json.
echo "== obs smoke (repro trace -- loadgen --check, identity pin under tracing) =="
rm -rf target/obs-smoke
cargo run --release -- trace -- loadgen --model synthetic:tiny_lm \
  --clients 2 --shards 2 --steps 50 \
  --snapshot target/obs-smoke/snapshot.bin --check \
  --trace-out target/obs-smoke/trace.json \
  --metrics-out target/obs-smoke/metrics.prom \
  --bench-json "${SMMF_SERVER_BENCH_JSON:-../BENCH_server.json}"
grep -q '"traceEvents"' target/obs-smoke/trace.json
grep -q '"name":"optim.factor_update"' target/obs-smoke/trace.json
grep -q '"name":"server.commit"' target/obs-smoke/trace.json
grep -q '^smmf_server_pushes_total 100$' target/obs-smoke/metrics.prom

# Grouped end-to-end: train -> save -> resume with a bias/norm-exempt
# group config through the real CLI. Needs AOT artifacts (make
# artifacts); self-skips when they are absent, matching the other
# artifact-gated surfaces.
if [ -d artifacts ]; then
  echo "== grouped config train -> save -> resume (lm_tiny_grads) =="
  rm -rf runs/grouped_smoke
  cargo run --release -- train --config tests/grouped_smoke.toml
  cargo run --release -- train --config tests/grouped_smoke.toml \
    --resume runs/grouped_smoke/checkpoint.bin --steps 60
else
  echo "== grouped config train skipped (no artifacts/ — run make artifacts) =="
fi

echo "== quick bench (SMMF_BENCH_QUICK=1) =="
SMMF_BENCH_JSON="${SMMF_BENCH_JSON:-../BENCH_optimizer_step.json}" \
SMMF_BENCH_QUICK=1 cargo bench --bench optimizer_step

echo "smoke OK"
