#!/usr/bin/env bash
# Pre-merge smoke: build, test, checkpoint-roundtrip, and quick-bench the
# optimizer suite so regressions in the fused/parallel step paths and the
# checkpoint/resume subsystem are caught before merge.
#
#   bash rust/tests/smoke.sh            # from the repo root
#   make smoke                          # equivalent
#
# The quick bench also refreshes BENCH_optimizer_step.json (the perf
# trajectory tracked across PRs, now including the SMMF-vs-Adam
# checkpoint size ratio) unless SMMF_BENCH_JSON overrides the output
# path.
set -euo pipefail

cd "$(dirname "$0")/.."   # rust/

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== checkpoint-roundtrip (bit-identical resume, all optimizers) =="
cargo test --release --test checkpoint_roundtrip

echo "== quick bench (SMMF_BENCH_QUICK=1) =="
SMMF_BENCH_JSON="${SMMF_BENCH_JSON:-../BENCH_optimizer_step.json}" \
SMMF_BENCH_QUICK=1 cargo bench --bench optimizer_step

echo "smoke OK"
