//! Checkpoint/resume correctness over the whole optimizer library.
//!
//! The acceptance bar (ISSUE 2): training 2N steps in one go must equal
//! N steps + save + load into a fresh process-worth of state + N more
//! steps, *bit for bit*, for all six optimizers, at `threads ∈ {1, 4}`
//! — plus v1-file compatibility, corrupt-file error paths, and the
//! on-disk SMMF-vs-Adam size ratio.
//!
//! The gradient stream is driven by a seeded `Pcg32` whose state is
//! saved in the checkpoint's TRAINER section, exactly as the train
//! loop's `BatchSource` RNG is — so the resumed scenario replays the
//! same "data" without rerunning the first half.

use std::path::PathBuf;

use smmf_repro::models::inventory_by_name;
use smmf_repro::optim::schedule::LrSchedule;
use smmf_repro::optim::{build, memory, OptKind, OptimConfig, Optimizer, SignMode, StateSerde};
use smmf_repro::tensor::Tensor;
use smmf_repro::train::checkpoint::{self, OptSection, ScheduleSection};
use smmf_repro::util::rng::Pcg32;

fn test_shapes() -> Vec<Vec<usize>> {
    // A mix that exercises every state layout: square-matricizable 2-D,
    // an odd-length vector (word-unaligned sign rows), a conv-ish rank-4,
    // a 1x1-conv pathology, and a scalar-ish tensor.
    vec![vec![24, 16], vec![65], vec![4, 3, 2, 2], vec![6, 4, 1, 1], vec![1]]
}

fn cfg_for(kind: OptKind, threads: usize) -> OptimConfig {
    OptimConfig {
        lr: 0.01,
        weight_decay: 0.01,
        threads,
        ..OptimConfig::paper_defaults(kind)
    }
}

fn rand_tensors(rng: &mut Pcg32, shapes: &[Vec<usize>], scale: f32) -> Vec<Tensor> {
    shapes
        .iter()
        .map(|s| {
            let mut t = Tensor::zeros(s);
            rng.fill_normal(t.data_mut(), scale);
            t
        })
        .collect()
}

fn tmp(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("smmf_ckpt_it_{tag}_{}.bin", std::process::id()))
}

/// Train `steps` steps from scratch; returns the final parameters.
fn run_straight(kind: OptKind, threads: usize, steps: usize) -> Vec<Tensor> {
    let shapes = test_shapes();
    let cfg = cfg_for(kind, threads);
    let mut opt = build(kind, &shapes, &cfg);
    let mut init_rng = Pcg32::new(7);
    let mut params = rand_tensors(&mut init_rng, &shapes, 0.5);
    let mut data_rng = Pcg32::new(123);
    for _ in 0..steps {
        let grads = rand_tensors(&mut data_rng, &shapes, 0.1);
        opt.step(&mut params, &grads);
    }
    params
}

/// Train `half` steps, checkpoint through an actual v2 file, rebuild
/// everything from the file alone, train to `total`.
fn run_resumed(kind: OptKind, threads: usize, half: usize, total: usize) -> Vec<Tensor> {
    let shapes = test_shapes();
    let cfg = cfg_for(kind, threads);
    let names: Vec<String> = (0..shapes.len()).map(|i| format!("p{i}")).collect();
    let path = tmp(&format!("{}_t{threads}", kind.name()));

    {
        let mut opt = build(kind, &shapes, &cfg);
        let mut init_rng = Pcg32::new(7);
        let mut params = rand_tensors(&mut init_rng, &shapes, 0.5);
        let mut data_rng = Pcg32::new(123);
        for _ in 0..half {
            let grads = rand_tensors(&mut data_rng, &shapes, 0.1);
            opt.step(&mut params, &grads);
        }
        let sched = ScheduleSection { base_lr: cfg.lr, schedule: LrSchedule::Constant };
        let opt_sec =
            OptSection { kind, opt_step: opt.opt_step(), blobs: opt.state_blobs() };
        checkpoint::save_v2(
            &path,
            half as u64,
            &names,
            &params,
            Some(data_rng.state()),
            Some(&sched),
            Some(&opt_sec),
            None,
        )
        .unwrap();
        // first-half state dropped here: the file is all that survives
    }

    let ck = checkpoint::load_any(&path).unwrap();
    std::fs::remove_file(&path).unwrap();
    assert_eq!(ck.step, half as u64);
    assert_eq!(ck.names, names);
    let o = ck.opt.expect("v2 checkpoint carries optimizer state");
    assert_eq!(o.kind, kind);
    let mut opt = build(kind, &shapes, &cfg);
    opt.load_state_blobs(&o.blobs).unwrap();
    opt.set_opt_step(o.opt_step);
    let mut params = ck.params;
    let (state, inc) = ck.rng.expect("v2 checkpoint carries the data-RNG snapshot");
    let mut data_rng = Pcg32::from_state(state, inc);
    for _ in half..total {
        let grads = rand_tensors(&mut data_rng, &shapes, 0.1);
        opt.step(&mut params, &grads);
    }
    params
}

#[test]
fn resume_is_bit_identical_for_every_optimizer_at_1_and_4_threads() {
    let (half, total) = (4usize, 8usize);
    for kind in OptKind::every() {
        for threads in [1usize, 4] {
            let straight = run_straight(kind, threads, total);
            let resumed = run_resumed(kind, threads, half, total);
            assert_eq!(
                straight,
                resumed,
                "{} at threads={threads}: resume diverged",
                kind.name()
            );
        }
    }
}

#[test]
fn state_blobs_roundtrip_identically() {
    // save -> load -> save must be a fixed point for every optimizer.
    let shapes = test_shapes();
    let mut rng = Pcg32::new(42);
    for kind in OptKind::every() {
        let cfg = cfg_for(kind, 1);
        let mut opt = build(kind, &shapes, &cfg);
        let mut params = rand_tensors(&mut rng, &shapes, 0.5);
        for _ in 0..3 {
            let grads = rand_tensors(&mut rng, &shapes, 0.1);
            opt.step(&mut params, &grads);
        }
        let blobs = opt.state_blobs();
        let mut fresh = build(kind, &shapes, &cfg);
        fresh.load_state_blobs(&blobs).unwrap();
        fresh.set_opt_step(opt.opt_step());
        assert_eq!(fresh.state_blobs(), blobs, "{}", kind.name());
        assert_eq!(fresh.opt_step(), 3, "{}", kind.name());
    }
}

#[test]
fn v1_checkpoint_still_reads_params() {
    let shapes = test_shapes();
    let mut rng = Pcg32::new(5);
    let params = rand_tensors(&mut rng, &shapes, 0.5);
    let names: Vec<String> = (0..shapes.len()).map(|i| format!("p{i}")).collect();
    let path = tmp("v1_compat");
    checkpoint::save(&path, 9, &names, &params).unwrap();
    let ck = checkpoint::load_any(&path).unwrap();
    std::fs::remove_file(&path).unwrap();
    assert_eq!(ck.version, checkpoint::VERSION_V1);
    assert_eq!(ck.step, 9);
    assert_eq!(ck.names, names);
    assert_eq!(ck.params, params);
    assert!(ck.opt.is_none(), "v1 has no optimizer state");
}

#[test]
fn truncated_and_corrupt_checkpoints_error_cleanly() {
    let shapes = vec![vec![8, 8]];
    let cfg = cfg_for(OptKind::Smmf, 1);
    let mut opt = build(OptKind::Smmf, &shapes, &cfg);
    let mut rng = Pcg32::new(1);
    let mut params = rand_tensors(&mut rng, &shapes, 0.5);
    let grads = rand_tensors(&mut rng, &shapes, 0.1);
    opt.step(&mut params, &grads);
    let names = vec!["w".to_string()];
    let opt_sec =
        OptSection { kind: OptKind::Smmf, opt_step: 1, blobs: opt.state_blobs() };
    let path = tmp("trunc");
    checkpoint::save_v2(&path, 1, &names, &params, None, None, Some(&opt_sec), None).unwrap();
    let full = std::fs::read(&path).unwrap();

    // Truncations at a spread of prefixes must all error (never panic).
    for frac in [0usize, 4, 9, 17, 33, 50, 75, 99] {
        let cut = full.len() * frac / 100;
        std::fs::write(&path, &full[..cut]).unwrap();
        assert!(checkpoint::load_any(&path).is_err(), "prefix {cut} parsed");
    }
    // Flip a magic byte.
    let mut bad = full.clone();
    bad[0] ^= 0xff;
    std::fs::write(&path, &bad).unwrap();
    assert!(checkpoint::load_any(&path).is_err());
    // Intact file still loads.
    std::fs::write(&path, &full).unwrap();
    assert!(checkpoint::load_any(&path).is_ok());
    std::fs::remove_file(&path).unwrap();
}

/// A failed snapshot write must not strand its `.tmp` sibling and must
/// surface the io error with the offending path (regression: the temp
/// file used to leak when the final rename failed).
#[test]
fn failed_snapshot_writes_remove_the_temp_and_name_the_path() {
    let shapes = vec![vec![8, 8]];
    let cfg = cfg_for(OptKind::Smmf, 1);
    let mut opt = build(OptKind::Smmf, &shapes, &cfg);
    let mut rng = Pcg32::new(11);
    let mut params = rand_tensors(&mut rng, &shapes, 0.5);
    let grads = rand_tensors(&mut rng, &shapes, 0.1);
    opt.step(&mut params, &grads);
    let names = vec!["w".to_string()];

    // Rename failure: the target exists and is a non-empty directory, so
    // the temp write itself succeeds and only the final rename fails —
    // the torn write's temp file must be cleaned up, not stranded.
    let dir = std::env::temp_dir().join(format!("smmf_ckpt_it_dir_{}", std::process::id()));
    std::fs::create_dir_all(dir.join("occupied")).unwrap();
    let e = checkpoint::save_v2(&dir, 1, &names, &params, None, None, None, None).unwrap_err();
    let msg = format!("{e:#}");
    assert!(msg.contains("renaming"), "{msg}");
    assert!(msg.contains(dir.file_name().unwrap().to_str().unwrap()), "{msg}");
    let mut side = dir.file_name().unwrap().to_os_string();
    side.push(".tmp");
    let tmp_sibling = dir.with_file_name(side);
    assert!(!tmp_sibling.exists(), "leaked {tmp_sibling:?}");

    // Create failure: the parent is a regular file, so the temp file
    // cannot even be created — the error still names the temp path.
    let blocker = tmp("parent_is_a_file");
    std::fs::write(&blocker, b"x").unwrap();
    let inside = blocker.join("x.bin");
    let e =
        checkpoint::save_v2(&inside, 1, &names, &params, None, None, None, None).unwrap_err();
    let msg = format!("{e:#}");
    assert!(msg.contains("writing") && msg.contains("x.bin.tmp"), "{msg}");

    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_file(&blocker).ok();
}

#[test]
fn mismatched_optimizer_state_is_rejected() {
    let shapes = test_shapes();
    let cfg = cfg_for(OptKind::Adam, 1);
    let mut adam = build(OptKind::Adam, &shapes, &cfg);
    let mut rng = Pcg32::new(2);
    let mut params = rand_tensors(&mut rng, &shapes, 0.5);
    let grads = rand_tensors(&mut rng, &shapes, 0.1);
    adam.step(&mut params, &grads);
    let blobs = adam.state_blobs();

    // Wrong optimizer family.
    let mut sgd = build(OptKind::Sgd, &shapes, &cfg_for(OptKind::Sgd, 1));
    assert!(sgd.load_state_blobs(&blobs).is_err());
    // Wrong tensor count.
    let mut adam2 = build(OptKind::Adam, &shapes[..2], &cfg);
    assert!(adam2.load_state_blobs(&blobs).is_err());
    // Sign-width config mismatch for SMMF.
    let smmf_cfg = cfg_for(OptKind::Smmf, 1);
    let mut smmf = build(OptKind::Smmf, &shapes, &smmf_cfg);
    smmf.step(&mut params, &grads);
    let smmf_blobs = smmf.state_blobs();
    let byte_cfg = OptimConfig { smmf_sign_mode: SignMode::Byte8, ..smmf_cfg };
    let mut smmf8 = build(OptKind::Smmf, &shapes, &byte_cfg);
    assert!(smmf8.load_state_blobs(&smmf_blobs).is_err());
}

#[test]
fn smmf_checkpoint_is_at_most_10pct_of_adams() {
    // Live serialized bytes on a moderate inventory…
    let shapes = vec![vec![512, 512], vec![256, 128], vec![768]];
    let smmf = build(OptKind::Smmf, &shapes, &OptimConfig::paper_defaults(OptKind::Smmf));
    let adam = build(OptKind::Adam, &shapes, &OptimConfig::paper_defaults(OptKind::Adam));
    let bytes = |o: &Box<dyn Optimizer>| -> u64 {
        o.state_blobs().iter().map(|b| b.len() as u64).sum()
    };
    assert!(
        10 * bytes(&smmf) <= bytes(&adam),
        "smmf {} vs adam {}",
        bytes(&smmf),
        bytes(&adam)
    );
    // …and analytically on a full paper inventory (too big to build).
    for model in ["transformer_base", "resnet50_imagenet", "gpt2_124m"] {
        let inv = inventory_by_name(model).unwrap();
        let shapes = inv.shapes();
        let s = memory::inventory_checkpoint_bytes(
            OptKind::Smmf,
            &shapes,
            &OptimConfig::paper_defaults(OptKind::Smmf),
        );
        let a = memory::inventory_checkpoint_bytes(
            OptKind::Adam,
            &shapes,
            &OptimConfig::paper_defaults(OptKind::Adam),
        );
        assert!(10 * s <= a, "{model}: smmf {s} vs adam {a}");
    }
}
