//! Integration tests for the observability subsystem: the flight
//! recorder under real shard parallelism, deterministic export bytes,
//! and the metrics registry's publish/read semantics.
//!
//! Tests that toggle the process-global trace flag serialize on
//! `GLOBAL_OBS` so the cross-thread test cannot race the inertness
//! test (the Rust harness runs tests concurrently).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Barrier, Mutex};

use smmf_repro::obs;
use smmf_repro::obs::export::{chrome_trace_json, prometheus_text};
use smmf_repro::obs::metrics::Registry;
use smmf_repro::obs::trace::{Clock, Phase, Recorder};
use smmf_repro::optim::parallel::{run_shards, Shard};

/// Serializes tests that flip `obs::set_trace_enabled` or read the
/// global recorder, so their event counts don't interleave.
static GLOBAL_OBS: Mutex<()> = Mutex::new(());

fn counter_clock(step: u64) -> Clock {
    let t = AtomicU64::new(0);
    Arc::new(move || t.fetch_add(step, Ordering::Relaxed))
}

/// `run_shards` spawns one worker per non-empty shard (the calling
/// thread doubles as the first); with tracing on, each busy shard's
/// task walk lands as one `optim.shard` span on that worker's own
/// ring — so the drain shows one span per busy shard, on distinct
/// thread ids, and empty shards contribute nothing.
#[test]
fn run_shards_records_one_span_per_busy_shard_across_threads() {
    let _g = GLOBAL_OBS.lock().unwrap_or_else(|p| p.into_inner());
    let before = obs::trace::global()
        .drain()
        .events
        .iter()
        .filter(|e| e.name == "optim.shard")
        .count();
    obs::set_trace_enabled(true);

    // Three busy shards + one empty one. A barrier inside the kernel
    // forces all three workers to be alive simultaneously, so the
    // spans genuinely come from three concurrent threads.
    let barrier = Arc::new(Barrier::new(3));
    let mut shards: Vec<Shard<(), u64>> = vec![
        Shard { ctx: (), tasks: vec![1, 2] },
        Shard { ctx: (), tasks: vec![3] },
        Shard { ctx: (), tasks: Vec::new() },
        Shard { ctx: (), tasks: vec![4] },
    ];
    let total = AtomicU64::new(0);
    run_shards(&mut shards, |_ctx, t| {
        if *t != 2 {
            // First task of each busy shard: rendezvous.
            barrier.wait();
        }
        total.fetch_add(*t, Ordering::Relaxed);
    });
    obs::set_trace_enabled(false);
    assert_eq!(total.load(Ordering::Relaxed), 10, "all tasks ran");

    let dump = obs::trace::global().drain();
    let spans: Vec<_> = dump
        .events
        .iter()
        .filter(|e| e.name == "optim.shard")
        .collect();
    assert_eq!(
        spans.len(),
        before + 3,
        "one span per busy shard, none for the empty one"
    );
    let mut tids: Vec<u64> = spans.iter().map(|e| e.tid).collect();
    tids.sort_unstable();
    tids.dedup();
    assert!(
        tids.len() >= 3,
        "three workers means three distinct thread rings, got tids {tids:?}"
    );
    for e in &spans {
        assert_eq!(e.cat, "optim");
        assert_eq!(e.ph, Phase::Complete);
    }
}

/// With tracing off, instrumented code paths record nothing — the
/// non-perturbation half of the flight-recorder contract, checked
/// through the same `run_shards` entry point production uses.
#[test]
fn run_shards_is_silent_when_tracing_disabled() {
    let _g = GLOBAL_OBS.lock().unwrap_or_else(|p| p.into_inner());
    obs::set_trace_enabled(false);
    let before = obs::trace::global().drain().events.len();
    let mut shards: Vec<Shard<(), u64>> =
        vec![Shard { ctx: (), tasks: vec![1] }, Shard { ctx: (), tasks: vec![2] }];
    run_shards(&mut shards, |_ctx, _t| {});
    assert_eq!(obs::trace::global().drain().events.len(), before);
}

/// Marks recorded from concurrently running threads land on separate
/// rings with distinct recorder-assigned tids, and `drain` merges them
/// into one timestamp-sorted stream.
#[test]
fn cross_thread_marks_get_distinct_tids_and_sorted_drain() {
    let rec = Arc::new(Recorder::with_clock(counter_clock(10)));
    let barrier = Arc::new(Barrier::new(3));
    let mut handles = Vec::new();
    for _ in 0..3 {
        let rec = Arc::clone(&rec);
        let barrier = Arc::clone(&barrier);
        handles.push(std::thread::spawn(move || {
            barrier.wait();
            rec.mark("test", "tick");
            rec.mark("test", "tock");
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let dump = rec.drain();
    assert_eq!(dump.events.len(), 6);
    assert_eq!(dump.dropped, 0);
    let mut tids: Vec<u64> = dump.events.iter().map(|e| e.tid).collect();
    tids.sort_unstable();
    tids.dedup();
    assert_eq!(tids.len(), 3, "one ring per recording thread");
    let ts: Vec<u64> = dump.events.iter().map(|e| e.ts_us).collect();
    let mut sorted = ts.clone();
    sorted.sort_unstable();
    assert_eq!(ts, sorted, "drain is timestamp-ordered across rings");
}

/// The full path a `repro trace` run takes — record with an injected
/// clock, drain, export — pins the Chrome trace bytes exactly. Object
/// keys are sorted and the drain order is deterministic, so this
/// string is stable across runs and platforms.
#[test]
fn chrome_trace_export_bytes_are_pinned_with_injected_clock() {
    let rec = Arc::new(Recorder::with_clock(counter_clock(7)));
    {
        let _step = rec.span("optim", "optim.step"); // opens at ts=0
        rec.mark("suite", "lane.submit"); // ts=7
    } // closes at ts=14 -> dur=14
    let json = chrome_trace_json(&rec.drain());
    assert_eq!(
        json,
        concat!(
            r#"{"droppedEvents":0,"traceEvents":["#,
            r#"{"cat":"optim","dur":14,"name":"optim.step","ph":"X","pid":1,"tid":1,"ts":0},"#,
            r#"{"cat":"suite","name":"lane.submit","ph":"i","pid":1,"s":"t","tid":1,"ts":7}"#,
            "]}\n"
        )
    );
}

/// A tiny ring overflows into `dropped`, and the exporter surfaces the
/// count as `droppedEvents` so a clipped trace is visibly clipped.
#[test]
fn ring_overflow_is_counted_and_exported() {
    let rec = Arc::new(Recorder::with_clock(counter_clock(1)).with_capacity(2));
    for _ in 0..5 {
        rec.mark("test", "m");
    }
    let dump = rec.drain();
    assert_eq!(dump.dropped, 3);
    assert_eq!(dump.events.len(), 2);
    // The survivors are the two newest marks.
    assert_eq!(
        dump.events.iter().map(|e| e.ts_us).collect::<Vec<_>>(),
        vec![3, 4]
    );
    let json = chrome_trace_json(&dump);
    assert!(
        json.starts_with(r#"{"droppedEvents":3,"#),
        "clipped trace must report its drop count: {json}"
    );
}

/// Registry semantics the server layer depends on: `counter`/`gauge`/
/// `histogram` are get-or-create (same handle back), `publish_*`
/// replaces the handle (a restarted server's fresh counters win), and
/// `value` reads counters before gauges.
#[test]
fn registry_get_or_create_and_publish_replace() {
    let r = Registry::new();
    let c1 = r.counter("server.pushes_total");
    let c2 = r.counter("server.pushes_total");
    assert!(Arc::ptr_eq(&c1, &c2), "get-or-create returns the same handle");
    c1.fetch_add(5, Ordering::Relaxed);
    assert_eq!(r.value("server.pushes_total"), Some(5));

    // A fresh handle published under the same name replaces the old
    // one — reads now follow the new server, not the dead one.
    let fresh = Arc::new(AtomicU64::new(100));
    r.publish_counter("server.pushes_total", Arc::clone(&fresh));
    assert_eq!(r.value("server.pushes_total"), Some(100));
    c1.fetch_add(1, Ordering::Relaxed);
    assert_eq!(r.value("server.pushes_total"), Some(100), "old handle is detached");

    r.gauge("server.epoch").store(7, Ordering::Relaxed);
    assert_eq!(r.value("server.epoch"), Some(7));
    assert_eq!(r.value("no.such.metric"), None);

    let h1 = r.histogram("server.commit_ms");
    let h2 = r.histogram("server.commit_ms");
    assert!(Arc::ptr_eq(&h1, &h2));
    h1.observe(2.0);
    assert_eq!(r.snapshot().histograms.len(), 1);
}

/// End-to-end exposition from a populated registry: every family shows
/// up typed and renamed (`.` -> `_`, `smmf_` prefix), and quantiles
/// appear only once the histogram has observations.
#[test]
fn exposition_renders_populated_registry() {
    let r = Registry::new();
    r.counter("remote.submits_total").store(9, Ordering::Relaxed);
    r.gauge("server.step").store(50, Ordering::Relaxed);
    let h = r.histogram("optim.step_ms");
    let text = prometheus_text(&r.snapshot());
    assert!(text.contains("# TYPE smmf_remote_submits_total counter\nsmmf_remote_submits_total 9\n"));
    assert!(text.contains("# TYPE smmf_server_step gauge\nsmmf_server_step 50\n"));
    assert!(text.contains("smmf_optim_step_ms_count 0\n"));
    assert!(!text.contains("quantile"), "empty histogram exports no quantiles");
    for _ in 0..10 {
        h.observe(1.0);
    }
    let text = prometheus_text(&r.snapshot());
    assert!(text.contains("smmf_optim_step_ms{quantile=\"0.5\"}"));
    assert!(text.contains("smmf_optim_step_ms{quantile=\"0.99\"}"));
    assert!(text.contains("smmf_optim_step_ms_count 10\n"));
}

/// The shared percentile/mean helpers keep the exact rank convention
/// `run_loadgen` always printed (nearest-rank on the sorted sample),
/// so consolidating the duplicated math did not move any report
/// number.
#[test]
fn percentile_and_mean_match_loadgen_convention() {
    let ms: Vec<f64> = (1..=100).map(|v| v as f64).collect();
    assert_eq!(obs::metrics::percentile(&ms, 0.50), 51.0);
    assert_eq!(obs::metrics::percentile(&ms, 0.99), 99.0);
    assert_eq!(obs::metrics::percentile(&ms, 1.0), 100.0);
    assert_eq!(obs::metrics::percentile(&ms, 0.0), 1.0);
    assert_eq!(obs::metrics::mean(&[2.0, 4.0]), 3.0);
    assert!(obs::metrics::percentile(&[], 0.5).is_nan());
    assert!(obs::metrics::mean(&[]).is_nan());
}
