# Convenience targets for the SMMF reproduction.
#
#   make build       release build of the Rust crate
#   make test        full test suite
#   make smoke       build + test + checkpoint-roundtrip + suite smoke +
#                    quick bench (refreshes BENCH_*.json); run before
#                    merging optimizer/engine/checkpoint changes
#   make suite-smoke tiny 2-optimizer × 1-model × 2-seed suite (pure
#                    Rust, no artifacts) run twice; asserts the report
#                    is byte-identical across re-entry
#   make serve-smoke loopback optimizer-state server: 4 clients × 2
#                    shards on synthetic:tiny_lm; asserts the snapshot
#                    is byte-identical to the single-process reference
#                    trainer and refreshes BENCH_server.json
#   make chaos-smoke fault-tolerance smoke: drop a client + kill a shard
#                    worker mid-run (--check pins the snapshot against
#                    the elastic reference trainer), then a slow client
#                    under an armed eviction deadline; refreshes
#                    BENCH_server.json with degraded-vs-healthy numbers
#   make async-smoke bounded-staleness smoke: async ingestion (window 4)
#                    with a straggler client, commit log recorded, then
#                    `repro replay` re-executes the log and the replayed
#                    snapshot is byte-compared against the server's
#   make remote-smoke distributed-suite smoke: two loopback `repro
#                    worker` daemons run the smoke suite over SMMFCELL,
#                    twice (second pass all-cached), then a local-pool
#                    pass — all three reports byte-compared
#   make stream-smoke paper-scale streaming smoke: the corruption
#                    battery + chunk-stream property tests, then
#                    `repro loadgen --check` at 1x/8x/64x inventory
#                    scale (64x exceeds the 1 MiB live-frame cap and
#                    only serves chunked; --check byte-compares the
#                    streamed snapshot against the dense reference);
#                    refreshes BENCH_server.json with the per-scale
#                    steps/s + bytes/step records
#   make obs-smoke   observability smoke: the obs test battery (ring
#                    wraparound, cross-thread interleaving, pinned
#                    export bytes, traced-vs-untraced snapshot
#                    identity), then `repro trace -- loadgen --check`
#                    (the bit-identity pin must hold with the flight
#                    recorder on) and a traced suite run; validates the
#                    Chrome trace JSON + Prometheus exposition and
#                    leaves measured obs/ records in the BENCH JSONs
#   make docs-check  regenerate docs/RESULTS.md from the checked-in
#                    fixture summaries, fail on diff, and verify every
#                    docs link / file:line anchor
#   make bench       full optimizer-step bench (slow)
#   make docs        rustdoc for the crate, warnings-clean (--no-deps)
#   make artifacts   AOT-lower the JAX/Pallas graphs (needs python + jax)

.PHONY: build test smoke suite-smoke serve-smoke chaos-smoke async-smoke remote-smoke stream-smoke obs-smoke docs-check bench docs artifacts

build:
	cd rust && cargo build --release

test:
	cd rust && cargo test -q

smoke:
	bash rust/tests/smoke.sh

suite-smoke:
	rm -rf runs/smoke
	cd rust && cargo run --release -- suite tests/suite_smoke.toml \
	  --out-dir ../runs --docs ../runs/smoke/RESULTS.md \
	  --bench-json ../runs/smoke/BENCH_suite.json
	cd rust && cargo run --release -- suite tests/suite_smoke.toml \
	  --out-dir ../runs --docs ../runs/smoke/RESULTS.2.md \
	  --bench-json ../runs/smoke/BENCH_suite.2.json
	cmp runs/smoke/RESULTS.md runs/smoke/RESULTS.2.md
	@echo "suite-smoke OK: report byte-identical across re-entry"

serve-smoke:
	cd rust && cargo run --release -- loadgen --model synthetic:tiny_lm \
	  --clients 4 --shards 2 --steps 30 \
	  --snapshot target/serve-smoke/snapshot.bin --check \
	  --bench-json ../BENCH_server.json
	@echo "serve-smoke OK: 2-shard x 4-client snapshot byte-identical to the single-process trainer"

chaos-smoke:
	cd rust && cargo run --release -- loadgen --model synthetic:tiny_lm \
	  --clients 3 --shards 2 --steps 20 \
	  --drop-client 8 --kill-shard 5 --client-timeout-ms 400 \
	  --snapshot target/chaos-smoke/snapshot.bin --check \
	  --bench-json target/chaos-smoke/BENCH_chaos.json
	cd rust && cargo run --release -- loadgen --model synthetic:tiny_lm \
	  --clients 3 --shards 2 --steps 12 \
	  --slow-client 40 --client-timeout-ms 2000 \
	  --bench-json ../BENCH_server.json
	@echo "chaos-smoke OK: survived a client drop + shard kill bit-identically, and a slow client under an armed deadline"

async-smoke:
	cd rust && cargo run --release -- loadgen --model synthetic:tiny_lm \
	  --clients 4 --shards 2 --steps 30 \
	  --staleness 4 --slow-client 20 \
	  --commit-log target/async-smoke/commits.bin \
	  --snapshot target/async-smoke/snapshot.bin \
	  --bench-json target/async-smoke/BENCH_async.json
	cd rust && cargo run --release -- replay target/async-smoke/commits.bin \
	  --shards 2 --snapshot target/async-smoke/replay.bin
	cmp rust/target/async-smoke/snapshot.bin rust/target/async-smoke/replay.bin
	@echo "async-smoke OK: commit-log replay byte-identical to the async server's snapshot"

remote-smoke:
	bash rust/tests/remote_smoke.sh

stream-smoke:
	bash rust/tests/stream_smoke.sh

obs-smoke:
	cd rust && cargo test --release --test obs
	rm -rf rust/target/obs-smoke
	cd rust && cargo run --release -- trace -- loadgen \
	  --model synthetic:tiny_lm --clients 2 --shards 2 --steps 50 \
	  --snapshot target/obs-smoke/snapshot.bin --check \
	  --trace-out target/obs-smoke/trace.json \
	  --metrics-out target/obs-smoke/metrics.prom \
	  --bench-json ../BENCH_server.json
	grep -q '"traceEvents"' rust/target/obs-smoke/trace.json
	grep -q '"name":"optim.factor_update"' rust/target/obs-smoke/trace.json
	grep -q '"name":"server.commit"' rust/target/obs-smoke/trace.json
	grep -q '^smmf_server_pushes_total 100$$' rust/target/obs-smoke/metrics.prom
	grep -q '"obs/server.commit_ms"' BENCH_server.json
	cd rust && cargo run --release -- trace -- suite tests/suite_smoke.toml \
	  --out-dir target/obs-smoke/suite --docs target/obs-smoke/RESULTS.md \
	  --bench-json target/obs-smoke/BENCH_suite.json \
	  --trace-out target/obs-smoke/suite-trace.json \
	  --metrics-out target/obs-smoke/suite-metrics.prom
	grep -q '"name":"optim.step"' rust/target/obs-smoke/suite-trace.json
	@echo "obs-smoke OK: traced loadgen stayed bit-identical; trace + exposition artifacts validated"

docs-check:
	cd rust && cargo run --release -- report tests/fixtures/suite_report/smoke \
	  --docs target/docs-check/RESULTS.md --bench-json target/docs-check/BENCH_suite.json
	cmp docs/RESULTS.md rust/target/docs-check/RESULTS.md || { \
	  echo "docs/RESULTS.md is stale vs the report generator —"; \
	  echo "regenerate with: cd rust && cargo run --release -- report \\"; \
	  echo "  tests/fixtures/suite_report/smoke --docs ../docs/RESULTS.md \\"; \
	  echo "  --bench-json target/docs-check/BENCH_suite.json"; \
	  exit 1; }
	bash rust/tests/check_docs_links.sh
	@echo "docs-check OK"

bench:
	cd rust && SMMF_BENCH_JSON=../BENCH_optimizer_step.json cargo bench --bench optimizer_step

docs:
	cd rust && RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

artifacts:
	python3 python/compile/aot.py
