# Convenience targets for the SMMF reproduction.
#
#   make build     release build of the Rust crate
#   make test      full test suite
#   make smoke     build + test + quick bench (refreshes BENCH_*.json);
#                  run this before merging optimizer/engine changes
#   make bench     full optimizer-step bench (slow)
#   make artifacts AOT-lower the JAX/Pallas graphs (needs python + jax)

.PHONY: build test smoke bench artifacts

build:
	cd rust && cargo build --release

test:
	cd rust && cargo test -q

smoke:
	bash rust/tests/smoke.sh

bench:
	cd rust && SMMF_BENCH_JSON=../BENCH_optimizer_step.json cargo bench --bench optimizer_step

artifacts:
	python3 python/compile/aot.py
