# Convenience targets for the SMMF reproduction.
#
#   make build     release build of the Rust crate
#   make test      full test suite
#   make smoke     build + test + checkpoint-roundtrip + quick bench
#                  (refreshes BENCH_*.json); run before merging
#                  optimizer/engine/checkpoint changes
#   make bench     full optimizer-step bench (slow)
#   make docs      rustdoc for the crate, warnings-clean (--no-deps)
#   make artifacts AOT-lower the JAX/Pallas graphs (needs python + jax)

.PHONY: build test smoke bench docs artifacts

build:
	cd rust && cargo build --release

test:
	cd rust && cargo test -q

smoke:
	bash rust/tests/smoke.sh

bench:
	cd rust && SMMF_BENCH_JSON=../BENCH_optimizer_step.json cargo bench --bench optimizer_step

docs:
	cd rust && RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

artifacts:
	python3 python/compile/aot.py
